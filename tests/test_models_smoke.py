"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes + finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStructs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, RunConfig, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding
from repro.data import make_batch_fn
from repro.launch.steps import make_train_step
from repro.models import build_model, init_params
from repro.optim import make_optimizer

B, S = 2, 64


def _make_batch(cfg, key):
    shape = ShapeConfig("t", seq_len=S, global_batch=B, mode="train")
    return {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape)(0).items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = _make_batch(cfg, jax.random.PRNGKey(1))

    loss = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"

    run = RunConfig(total_steps=10, microbatches=1)
    opt = make_optimizer(cfg.optimizer, run)
    plan = plan_sharding(cfg, None, None)
    step = jax.jit(make_train_step(model, opt, plan, run))
    opt_state = opt.init(params)
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # params actually changed and stayed finite
    changed = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(changed)) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Greedy decode after prefill must agree with teacher-forced forward."""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    batch = _make_batch(cfg, jax.random.PRNGKey(1))
    pre = dict(batch)
    pre.pop("labels", None)

    logits_pre, cache = jax.jit(model.prefill)(params, pre)
    tok = batch["tokens"][:, :1]
    logits_dec, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits_dec.shape[0] == B and logits_dec.shape[1] == 1
    assert logits_dec.shape[-1] == cfg.vocab_size
    assert bool(jnp.isfinite(logits_dec.astype(jnp.float32)).all()), arch
    # per-sequence positions (continuous batching): pos is (B,)
    assert cache2["pos"].shape == (B,)
    np.testing.assert_array_equal(np.asarray(cache2["pos"]),
                                  np.asarray(cache["pos"]) + 1)


def test_microbatched_step_matches_full():
    """Grad accumulation is loss/step-equivalent to the full batch."""
    cfg = get_config("pimref-100m", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, mode="train")
    batch = {k: jnp.asarray(v)
             for k, v in make_batch_fn(cfg, shape)(0).items()}
    plan = plan_sharding(cfg, None, None)
    run1 = RunConfig(total_steps=10, microbatches=1)
    run2 = RunConfig(total_steps=10, microbatches=2)
    opt = make_optimizer("sgd", run1)
    p1, _, m1 = jax.jit(make_train_step(model, opt, plan, run1))(
        params, opt.init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, opt, plan, run2))(
        params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=1e-4)
