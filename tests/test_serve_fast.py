"""Fused decode + continuous batching correctness.

The fused generate step (whole decode loop in one jit) must be a pure
performance transform: byte-identical greedy tokens vs the per-token loop,
cache donated in place, and the slot-based engine must drain mixed-length
queues with compile-cache hits after warmup.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.launch.serve import serve
from repro.launch.steps import (make_decode_step, make_generate_step,
                                make_prefill_step, sample_tokens)
from repro.models import build_model, init_params

# decoder LM / recurrent (RG-LRU hybrid) / MoE
ARCHS = ["pimref-100m", "recurrentgemma-2b", "mixtral-8x7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_fused_matches_per_token_loop(arch):
    """Greedy tokens from the fused scan == per-token loop, byte-identical."""
    kw = dict(smoke=True, batch=2, prompt_len=16, gen=12, chunk=4)
    loop = serve(arch, engine="loop", **kw)
    fused = serve(arch, engine="fused", **kw)
    np.testing.assert_array_equal(loop["tokens"], fused["tokens"])
    assert loop["dispatches"] == 12
    assert fused["dispatches"] == 3          # one dispatch per 4-token chunk


def _build(arch, batch, prompt_len, max_len):
    cfg = get_config(arch, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, ShapeConfig("serve", max_len, batch, "decode"),
                        mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params, plan


def test_generate_cache_donated():
    """The fused step updates the cache in place: the input buffers are
    consumed (no second live copy of the KV cache)."""
    cfg, model, params, plan = _build("pimref-100m", 2, 8, 16)
    prefill = jax.jit(make_prefill_step(model, plan, max_len=16))
    generate = jax.jit(make_generate_step(model, plan, chunk=4),
                       donate_argnums=(1,))
    toks = jnp.zeros((2, 8), jnp.int32)
    _, cache = prefill(params, {"tokens": toks})
    k_in = cache["k"]
    cache, tok, key, done, n_valid, out, _failed = generate(
        params, cache, jnp.zeros((2, 1), jnp.int32), jax.random.PRNGKey(0),
        jnp.int32(-1))
    assert k_in.is_deleted(), "cache was copied, not donated"
    assert out.shape == (2, 4)
    assert not np.asarray(done).any()            # eos disabled (-1)
    np.testing.assert_array_equal(np.asarray(n_valid), [4, 4])


def test_sample_tokens_modes():
    logits = jnp.asarray([[0.1, 3.0, -1.0, 0.5], [2.0, 0.0, 1.9, -2.0]])
    key = jax.random.PRNGKey(0)
    greedy = sample_tokens(logits, key, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(greedy), [1, 0])
    # top_k=1 at any temperature collapses to argmax
    top1 = sample_tokens(logits, key, temperature=1.0, top_k=1)
    np.testing.assert_array_equal(np.asarray(top1), [1, 0])
    # top_k=2 only ever emits the two best tokens
    for seed in range(5):
        s = sample_tokens(logits, jax.random.PRNGKey(seed), temperature=5.0,
                          top_k=2)
        assert int(s[0]) in (1, 3) and int(s[1]) in (0, 2)


def _reference_greedy(model, params, plan, prompt, prompt_len, max_len, n):
    """Per-token greedy loop for one left-padded request (batch=1)."""
    prefill = jax.jit(make_prefill_step(model, plan, max_len=max_len))
    decode = jax.jit(make_decode_step(model, plan))
    toks = np.zeros((1, prompt_len), np.int32)
    t = np.asarray(prompt, np.int32)[-prompt_len:]
    toks[0, prompt_len - len(t):] = t
    logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = []
    for _ in range(n):
        out.append(int(tok[0, 0]))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    return out


def test_engine_drains_mixed_queue():
    """Continuous batching: mixed-length prompts/budgets through 2 slots
    produce exactly the single-request greedy outputs, with slot reuse and
    no recompilation after warmup."""
    prompt_len, max_new, chunk, slots = 8, 10, 4, 2
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      prompt_len + max_new)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        rng.integers(3, prompt_len + 1)),
                    max_new_tokens=n)
            for i, n in enumerate([3, 10, 5, 2, 7])]

    eng = ServeEngine(model, params, plan, slots=slots, prompt_len=prompt_len,
                      max_new=max_new, chunk=chunk)
    comps = {c.uid: c for c in eng.run(list(reqs))}

    assert len(comps) == len(reqs) > slots          # slots were reused
    assert eng.stats["prefills"] == len(reqs)
    # fused decode: far fewer dispatches than tokens
    assert eng.stats["decode_dispatches"] < eng.stats["tokens_out"]
    assert eng.compile_cache_size() in (None, 1)    # no recompile after warmup

    for req in reqs:
        ref = _reference_greedy(model, params, plan, req.tokens, prompt_len,
                                eng.max_len, req.max_new_tokens)
        got = comps[req.uid]
        assert got.finish_reason == "length"
        np.testing.assert_array_equal(got.tokens, ref,
                                      err_msg=f"request {req.uid}")

    # EOS handling reuses the same compiled engine (on-device done flag)
    probe = _reference_greedy(model, params, plan, reqs[1].tokens, prompt_len,
                              eng.max_len, max_new)
    eos = probe[4]
    stop = probe.index(eos)                         # first occurrence
    eng.eos_id = eos
    eng.submit(Request(uid=99, tokens=reqs[1].tokens, max_new_tokens=max_new))
    eng.run()
    done = {c.uid: c for c in eng.completions}[99]
    assert done.finish_reason == "eos"
    np.testing.assert_array_equal(done.tokens, probe[:stop + 1])


def test_generate_step_on_device_eos():
    """EOS detection inside the fused scan: the done flag latches per slot,
    tokens after EOS are frozen to the EOS token, and n_valid counts up to
    and including it — the engine retires slots without host-side scans."""
    cfg, model, params, plan = _build("pimref-100m", 2, 8, 24)
    prefill = jax.jit(make_prefill_step(model, plan, max_len=24))
    generate = jax.jit(make_generate_step(model, plan, chunk=8),
                       donate_argnums=(1,))
    toks = jnp.zeros((2, 8), jnp.int32)
    logits, cache = prefill(params, {"tokens": toks})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    # first run with eos disabled to learn the greedy stream
    ref_cache = jax.tree_util.tree_map(jnp.copy, cache)
    _, _, _, done, n, ref, _ = generate(params, ref_cache, tok,
                                        jax.random.PRNGKey(0), jnp.int32(-1))
    ref = np.asarray(ref)
    assert not np.asarray(done).any() and (np.asarray(n) == 8).all()

    # pick row 0's 4th greedy token as EOS and replay
    eos = int(ref[0, 3])
    stop = int(np.argmax(ref[0] == eos))            # first occurrence
    _, _, _, done, n, out, _ = generate(params, cache, tok,
                                        jax.random.PRNGKey(0), jnp.int32(eos))
    out, done, n = np.asarray(out), np.asarray(done), np.asarray(n)
    assert done[0] and n[0] == stop + 1
    np.testing.assert_array_equal(out[0, :stop + 1], ref[0, :stop + 1])
    assert (out[0, stop:] == eos).all()             # frozen after EOS
    # row 1 (no EOS in stream, unless it shares the token) stays untouched
    if eos not in ref[1]:
        assert not done[1] and n[1] == 8
        np.testing.assert_array_equal(out[1], ref[1])


def test_engine_quantized_kv_greedy_agreement(monkeypatch):
    """ServeEngine queue drain with REPRO_KV_QUANT=int8: every completion
    equals the single-request per-token greedy reference traced under the
    same quantized cache — the Proteus cache is numerics-consistent across
    the fused scan, slot swaps, and the per-token loop."""
    monkeypatch.setenv("REPRO_KV_QUANT", "int8")
    prompt_len, max_new, chunk, slots = 8, 8, 4, 2
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      prompt_len + max_new)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        rng.integers(3, prompt_len + 1)),
                    max_new_tokens=n)
            for i, n in enumerate([3, 8, 5, 2])]
    eng = ServeEngine(model, params, plan, slots=slots, prompt_len=prompt_len,
                      max_new=max_new, chunk=chunk)
    comps = {c.uid: c for c in eng.run(list(reqs))}
    assert len(comps) == len(reqs) > slots
    for req in reqs:
        ref = _reference_greedy(model, params, plan, req.tokens, prompt_len,
                                eng.max_len, req.max_new_tokens)
        np.testing.assert_array_equal(comps[req.uid].tokens, ref,
                                      err_msg=f"request {req.uid}")
