"""Sharded serving fleet: health-checked dispatch and snapshot failover.

The contract under test, one level above ``test_robust_serving``: the
*shard* is the failure domain. A fleet of N engine shards behind one
dispatcher guarantees exactly one Completion per submitted request —
through shard kills, stalls, and dropped heartbeats — with surviving
outputs byte-identical to an undisturbed single-engine drain, and the
typed ``shard_lost`` reason only when replay is impossible.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.distributed import fault_tolerance as ft
from repro.distributed.chaos import ShardChaosConfig, ShardChaosMonkey
from repro.distributed.dispatcher import Dispatcher
from repro.distributed.fault_tolerance import (HealthMonitor, RestartManifest,
                                               ShardState)
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.launch.fleet import ServeFleet
from repro.models import build_model, init_params

PS = 4
ARCH = "pimref-100m"


def _engine(slots=2, prompt_len=8, max_new=8, chunk=4, **kw):
    cfg = get_config(ARCH, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(
        cfg, ShapeConfig("serve", prompt_len + max_new, slots, "decode"),
        mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return ServeEngine(model, params, plan, slots=slots,
                       prompt_len=prompt_len, max_new=max_new, chunk=chunk,
                       **kw)


def _requests(n, prompt_len=8, max_new=8, seed=0):
    """Mixed-length prompts (the ROADMAP's 'mixed queue'): short ones keep
    prompt + produced inside the bucket (paged failover resumes from partial
    tokens), long ones overflow it (failover regenerates) — both replay
    paths run in every chaos drain."""
    cfg = get_config(ARCH, smoke=True)
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        ln = int(rng.integers(3, prompt_len + 1))
        reqs.append(Request(
            uid=i, tokens=rng.integers(1, cfg.vocab_size, ln).astype(np.int32),
            max_new_tokens=max_new))
    return reqs


def _fleet(shards=2, chaos=None, **fleet_kw):
    return ServeFleet(lambda sid: _engine(), shards=shards,
                      chaos=chaos, **fleet_kw)


def _assert_exactly_one_each(fleet, n):
    uids = sorted(c.uid for c in fleet.completions)
    assert uids == list(range(n)), uids


def _assert_identical(fleet, ref_by_uid):
    """Non-error fleet completions match the reference byte-for-byte."""
    checked = 0
    for c in fleet.completions:
        if c.finish_reason == "error":
            continue
        want = ref_by_uid[c.uid]
        assert list(np.asarray(c.tokens)) == list(np.asarray(want.tokens)), (
            f"uid={c.uid}: {np.asarray(c.tokens)} != "
            f"{np.asarray(want.tokens)}")
        checked += 1
    return checked


@pytest.fixture(scope="module")
def ref_paged():
    """Single-engine paged drain of the standard queue — the byte-identity
    oracle every fleet drain is compared against."""
    os.environ["REPRO_KV_PAGES"] = str(PS)
    try:
        eng = _engine()
        eng.run(_requests(6))
    finally:
        os.environ.pop("REPRO_KV_PAGES", None)
    return {c.uid: c for c in eng.completions}


# ---------------------------------------------------------------------------
# Control plane units (no engine builds)
# ---------------------------------------------------------------------------
def test_health_monitor_escalation_and_sticky_death():
    m = HealthMonitor(2, miss_suspect=2, miss_dead=4)
    assert m.state(0) is ShardState.LIVE and m.live_shards == [0, 1]
    assert m.miss(0, 0) is ShardState.LIVE          # one miss: still live
    assert m.miss(0, 1) is ShardState.SUSPECT       # threshold
    assert m.beat(0, 2) is ShardState.LIVE          # heartbeat revives
    assert m.recoveries == 1 and m.suspects == 1
    for step in range(4):
        m.miss(0, 3 + step)
    assert m.state(0) is ShardState.DEAD and m.deaths == 1
    assert m.beat(0, 9) is ShardState.DEAD          # zombies stay dead
    assert m.dead_shards == [0] and m.live_shards == [1]
    assert [e["kind"] for e in m.events] == ["suspect", "recover", "suspect",
                                             "dead"]
    assert m.mark_dead(0, 10, "again") is ShardState.DEAD
    assert m.deaths == 1                            # idempotent


def test_dispatcher_least_loaded_with_reservation_tiebreak():
    mon = HealthMonitor(3)
    d = Dispatcher(mon)
    assert d.route() == 0                           # all idle: lowest sid
    d.assign(10, 0)
    assert d.route() == 1
    d.assign(11, 1)
    d.note_reserved(2, 7)                           # loads equal below:
    d.assign(12, 2)
    d.note_reserved(0, 3)
    d.note_reserved(1, 5)
    assert d.route() == 0                           # fewest reserved pages
    assert d.route(exclude={0}) == 1
    mon.states[0] = ShardState.SUSPECT
    assert d.route() == 1                           # suspect: no new work
    mon.states[1] = mon.states[2] = ShardState.DEAD
    assert d.route() == 0                           # only suspect left
    mon.states[0] = ShardState.DEAD
    assert d.route() is None                        # fleet dead
    assert d.fail_shard(1) == [11] and d.outstanding == 2
    d.complete(10)
    assert d.outstanding == 1 and d.home(12) == 2


def test_shard_chaos_parse_seeding_and_fire_once():
    cfg = ShardChaosConfig.parse("kill=1@2, stall=0@4,drop=1@3x2", seed=5)
    assert cfg.kill_targets == {1: 2} and cfg.stall_targets == {0: 4}
    assert cfg.drop_targets == {1: (3, 2)} and cfg.armed and cfg.seed == 5
    with pytest.raises(ValueError, match="unknown shard fault"):
        ShardChaosConfig.parse("explode=1@2")
    assert not ShardChaosConfig().armed

    mk = ShardChaosMonkey(cfg, 2)
    assert mk.directive(1, 2)["kind"] == "kill"
    assert mk.directive(1, 2) is None               # fire-once
    assert mk.directive(0, 4)["steps"] == cfg.stall_steps
    assert mk.directive(1, 3)["beats"] == 2
    assert [e["kind"] for e in mk.events] == ["kill", "stall", "drop"]

    seeded = ShardChaosMonkey(ShardChaosConfig.parse("kills=1,seed=3"), 4)
    again = ShardChaosMonkey(ShardChaosConfig.parse("kills=1,seed=3"), 4)
    assert seeded._plan == again._plan and len(seeded._plan) == 1


def test_restart_manifest_save_is_atomic(tmp_path, monkeypatch):
    """A crash mid-save must leave the previous manifest intact and no tmp
    turd behind — regression for the pre-atomic torn-write window."""
    path = str(tmp_path / "manifest.json")
    man = RestartManifest(step=1, checkpoint_dir="", mesh_shape=[1],
                          mesh_axes=["data"], data_seed=0)
    man.save(path)
    assert RestartManifest.load(path).step == 1

    def torn_dump(obj, f, *a, **kw):
        f.write('{"step": 999, "torn":')            # partial bytes hit disk
        raise RuntimeError("killed mid-save")

    monkeypatch.setattr(ft.json, "dump", torn_dump)
    with pytest.raises(RuntimeError, match="killed mid-save"):
        RestartManifest(step=2, checkpoint_dir="", mesh_shape=[1],
                        mesh_axes=["data"], data_seed=0).save(path)
    monkeypatch.undo()
    assert RestartManifest.load(path).step == 1     # old manifest survives
    assert os.listdir(tmp_path) == ["manifest.json"]  # tmp cleaned up


# ---------------------------------------------------------------------------
# In-process fleet: identity, failover, health transitions
# ---------------------------------------------------------------------------
def test_two_shard_fleet_drains_byte_identical_to_one(monkeypatch, ref_paged):
    """The ROADMAP gate, in-process half: the same mixed queue drains to the
    same bytes through 2 shards, 1 shard, and a bare engine."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    for shards in (2, 1):
        fleet = _fleet(shards=shards)
        fleet.run(_requests(6))
        _assert_exactly_one_each(fleet, 6)
        assert _assert_identical(fleet, ref_paged) == 6
        assert fleet.stats["failovers"] == 0
        assert fleet.stats["error_completions"] == 0
        if shards == 2:   # both shards actually served
            per = fleet.per_shard_stats()
            assert all(r["tokens_out"] > 0 for r in per)
            assert sum(r["tokens_out"] for r in per) == \
                fleet.stats["tokens_out"]


@pytest.mark.parametrize("layout", ["contig", "paged"])
def test_shard_kill_mid_drain_fails_over_exactly_once(monkeypatch, tmp_path,
                                                      layout, ref_paged):
    """Chaos kill mid-drain: every request still completes exactly once,
    byte-identical to the undisturbed drain (paged shards resume from the
    checkpointed partial tokens; contiguous shards regenerate), and the
    per-shard RestartManifest checkpoints land on disk."""
    if layout == "paged":
        monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
        ref = ref_paged
    else:
        eng = _engine()
        eng.run(_requests(6))
        ref = {c.uid: c for c in eng.completions}
    # kill at step 1: the victim's slot-resident requests are mid-decode
    # with one checkpointed chunk, so failover replays partial progress
    fleet = _fleet(chaos=ShardChaosConfig.parse("kill=1@1"),
                   manifest_dir=str(tmp_path))
    fleet.run(_requests(6))
    _assert_exactly_one_each(fleet, 6)
    assert fleet.stats["failovers"] == 1
    assert fleet.stats["replays"] >= 1
    assert fleet.stats["shard_lost"] == 0           # survivor absorbed it all
    assert fleet.monitor.state(1) is ShardState.DEAD
    assert _assert_identical(fleet, ref) == 6       # no errors at all
    # the periodic checkpoints are atomic RestartManifests, one per shard
    man = RestartManifest.load(str(tmp_path / "shard0.json"))
    assert man.shape == "fleet-shard0" and man.serve is not None
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_stalled_shard_escalates_miss_suspect_dead_then_fails_over(
        monkeypatch, ref_paged):
    """A hung shard (no reply, not dead) walks the miss -> suspect -> dead
    escalation before failover — and its requests still drain identically
    because the stall did no work after the last checkpoint."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    fleet = _fleet(chaos=ShardChaosConfig.parse("stall=1@1"),
                   miss_suspect=2, miss_dead=3)
    fleet.run(_requests(6))
    _assert_exactly_one_each(fleet, 6)
    assert fleet.monitor.state(1) is ShardState.DEAD
    assert fleet.monitor.suspects == 1 and fleet.stats["failovers"] == 1
    kinds = [e["kind"] for e in fleet.monitor.events]
    assert kinds == ["suspect", "dead"]
    assert fleet.stats["heartbeat_misses"] >= 3
    assert _assert_identical(fleet, ref_paged) == 6


def test_dropped_heartbeats_suspect_then_recover_without_failover(
        monkeypatch, ref_paged):
    """Dropped heartbeats from a shard that keeps working: SUSPECT pauses
    new routing, the next beat revives it, and nothing fails over."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    fleet = _fleet(chaos=ShardChaosConfig.parse("drop=1@1x2"),
                   miss_suspect=2, miss_dead=6)
    fleet.run(_requests(6))
    _assert_exactly_one_each(fleet, 6)
    assert fleet.stats["failovers"] == 0
    assert fleet.monitor.suspects == 1 and fleet.monitor.recoveries == 1
    assert fleet.monitor.state(1) is ShardState.LIVE
    assert _assert_identical(fleet, ref_paged) == 6


def test_whole_fleet_dead_yields_typed_shard_lost(monkeypatch):
    """No survivor to replay on: outstanding requests complete with the
    typed ``shard_lost`` reason, partial tokens preserved from the last
    checkpoint — and late submissions are refused the same way. The
    exactly-one invariant survives total fleet loss."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    fleet = _fleet(shards=1, chaos=ShardChaosConfig.parse("kill=0@1"))
    fleet.run(_requests(3))
    _assert_exactly_one_each(fleet, 3)
    comps = {c.uid: c for c in fleet.completions}
    assert all(c.finish_reason == "error" and c.reason == "shard_lost"
               for c in comps.values())
    # the two slot-resident requests got one chunk (step 0) checkpointed
    assert sorted(len(c.tokens) for c in comps.values()) == [0, 4, 4]
    assert fleet.stats["shard_lost"] == 3
    fleet.submit(Request(uid=99, tokens=np.arange(1, 5, dtype=np.int32),
                         max_new_tokens=4))
    late = [c for c in fleet.completions if c.uid == 99]
    assert len(late) == 1 and late[0].reason == "shard_lost"


# ---------------------------------------------------------------------------
# Multiprocessing shards (the CPU multi-host gate)
# ---------------------------------------------------------------------------
def test_mp_two_shard_fleet_drains_byte_identical(ref_paged, monkeypatch):
    """The ROADMAP gate: a 2-shard multiprocessing fleet drains the mixed
    queue byte-identical to a single engine, with both workers serving."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    from repro.launch.serve import make_fleet
    fleet = make_fleet(ARCH, shards=2, backend="mp", slots=2, prompt_len=8,
                       gen=8, chunk=4, seed=0)
    try:
        fleet.run(_requests(6))
        _assert_exactly_one_each(fleet, 6)
        assert _assert_identical(fleet, ref_paged) == 6
        per = fleet.per_shard_stats()
        assert all(r["tokens_out"] > 0 for r in per)
    finally:
        fleet.close()


def test_mp_shard_kill_is_a_real_terminate_and_fails_over(ref_paged,
                                                          monkeypatch):
    """Chaos kill on the mp backend SIGKILLs the worker process; the fleet
    detects death through process liveness (not a cooperative flag), fails
    over, and still delivers every request exactly once, byte-identical."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    from repro.launch.serve import make_fleet
    fleet = make_fleet(ARCH, shards=2, backend="mp", slots=2, prompt_len=8,
                       gen=8, chunk=4, seed=0,
                       fleet_chaos=ShardChaosConfig.parse("kill=1@2"))
    try:
        fleet.run(_requests(6))
        _assert_exactly_one_each(fleet, 6)
        assert fleet.stats["failovers"] == 1
        assert fleet.monitor.state(1) is ShardState.DEAD
        assert not fleet.shards[1].proc.is_alive()
        assert _assert_identical(fleet, ref_paged) == 6
    finally:
        fleet.close()
