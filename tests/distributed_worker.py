"""Subprocess worker for multi-device tests (run with XLA_FLAGS=8 devices).

Usage: python distributed_worker.py <mode>
Prints 'PASS <mode>' on success; any exception exits nonzero.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import make_mesh, shard_map
from repro.configs import RunConfig, ShapeConfig, get_config
from repro.core import dappa, proteus
from repro.core.mimdram import plan_sharding, use_plan
from repro.data import make_batch_fn
from repro.launch.steps import (cell_artifacts, make_train_step,
                                make_train_step_proteus)
from repro.models import build_model, init_params
from repro.optim import make_optimizer

MODE = sys.argv[1]
assert len(jax.devices()) == 8, jax.devices()


def almost(a, b, tol=1e-4):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    assert np.allclose(a, b, rtol=tol, atol=tol), (a, b, np.abs(a - b).max())


if MODE == "sharding_invariance":
    # loss identical on 1 device vs 4x2 mesh with full planner sharding
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    shape = ShapeConfig("t", seq_len=64, global_batch=8, mode="train")
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape)(0).items()}
    loss_1 = jax.jit(model.loss)(params, batch)

    mesh = make_mesh((4, 2), ("data", "model"))
    plan = plan_sharding(cfg, shape, mesh)

    def loss_fn(p, b):
        with use_plan(plan):
            return model.loss(p, b)

    from repro.models import module as mod
    pspecs = mod.param_pspecs(model.param_specs(), plan)
    psh = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.device_put(params, psh)
    bsh = {k: jax.device_put(v, NamedSharding(
        mesh, P("data") if v.ndim == 2 else P("data", None, None)))
        for k, v in batch.items()}
    loss_8 = jax.jit(loss_fn)(params_sh, bsh)
    almost(loss_1, loss_8, 2e-3)
    print("PASS sharding_invariance")

elif MODE == "dappa_distributed":
    mesh = make_mesh((8,), ("data",))
    x = dappa.input_stream("x")
    y = dappa.input_stream("y")
    dot = x.zip(y).map(lambda t: t[..., 0] * t[..., 1]).reduce("sum")
    mov = x.window(4, lambda w: w.max(-1))
    fm = x.filter(lambda v: v > 0).reduce("mean")
    fd = dappa.compile_pipeline({"d": dot, "m": mov, "f": fm}, mesh=mesh)
    fl = dappa.compile_pipeline({"d": dot, "m": mov, "f": fm})
    xs = jnp.linspace(-3, 3, 64)
    ys = jnp.linspace(1, 2, 64)
    od, ol = fd(x=xs, y=ys), fl(x=xs, y=ys)
    for k in od:
        almost(od[k], ol[k], 1e-5)
    print("PASS dappa_distributed")

elif MODE == "proteus_psum":
    mesh = make_mesh((8,), ("pod",))

    def worker(g):
        exact = jax.lax.psum(g, "pod")
        q8 = proteus.proteus_psum(g, "pod", bits=8, block=128)
        q4 = proteus.proteus_psum(g, "pod", bits=4, block=128)
        return exact, q8, q4

    g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024), jnp.float32)
    f = shard_map(worker, mesh=mesh, in_specs=P("pod"),
                  out_specs=(P("pod"), P("pod"), P("pod")), check_vma=False)
    exact, q8, q4 = f(g)
    # error bound: n_dev * scale/2 per element, scale = gmax/qmax per block
    err8 = np.abs(np.asarray(q8 - exact))
    err4 = np.abs(np.asarray(q4 - exact))
    gmax = np.abs(np.asarray(g)).max()
    assert err8.max() <= 8 * (gmax / 127) / 2 * 1.01 + 1e-6, err8.max()
    assert err4.max() <= 8 * (gmax / 7) / 2 * 1.01 + 1e-6, err4.max()
    assert err8.mean() < err4.mean()  # more bits -> tighter
    print("PASS proteus_psum")

elif MODE == "proteus_train_step":
    # 2-pod mesh: quantized cross-pod grad reduction trains and tracks baseline
    cfg = get_config("pimref-100m", smoke=True)
    model = build_model(cfg)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeConfig("t", seq_len=32, global_batch=8, mode="train")
    plan = plan_sharding(cfg, shape, mesh)
    run = RunConfig(total_steps=10, microbatches=1, proteus_enabled=True,
                    proteus_grad_bits=8, proteus_block=128)
    params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", run)
    ostate = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape)(0).items()}

    base_step = jax.jit(make_train_step(model, opt, plan, run))
    prot_step = jax.jit(make_train_step_proteus(model, opt, plan, run))
    p1, o1, m1 = base_step(params, ostate, batch)
    p2, o2, m2 = prot_step(params, ostate, batch)
    almost(m1["loss"], m2["loss"], 1e-3)
    # parameters close after one step (quantization noise bounded)
    d = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
            for a, b in zip(jax.tree_util.tree_leaves(p1),
                            jax.tree_util.tree_leaves(p2)))
    assert d < 1e-3, d
    print("PASS proteus_train_step")

elif MODE == "mini_dryrun":
    # the full dry-run machinery on a (2,2,2) mesh with smoke configs
    from repro.core import damov
    for arch in ("internlm2-1.8b", "mixtral-8x7b", "recurrentgemma-2b"):
        cfg = get_config(arch, smoke=True)
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
        for mode, seq, gb in (("train", 64, 8), ("decode", 64, 8)):
            shape = ShapeConfig("t", seq_len=seq, global_batch=gb, mode=mode)
            plan = plan_sharding(cfg, shape, mesh)
            model, step, args, shardings, donate, _, out_sh = cell_artifacts(
                cfg, shape, plan, RunConfig(microbatches=1))
            c = jax.jit(step, in_shardings=shardings, out_shardings=out_sh,
                        donate_argnums=donate or None).lower(*args).compile()
            st = damov.analyze_hlo(c.as_text())
            assert st.flops > 0
            assert c.memory_analysis() is not None
    print("PASS mini_dryrun")

elif MODE == "pipeline":
    # GPipe over a 2-stage pod axis == sequential stack, bit-for-bit
    from repro.distributed.pipeline import bubble_fraction, pipelined_forward
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    L, D, M, mb = 4, 16, 4, 8
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (L, D, D)) * 0.3

    def block(wl, h):
        return jnp.tanh(h @ wl)

    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, D))
    out = jax.jit(lambda w, x: pipelined_forward(
        block, w, x, mesh=mesh, n_stages=2, n_layers=L))(w, x)
    ref = x
    for i in range(L):
        ref = jnp.tanh(ref @ w[i])
    almost(out, ref, 1e-5)
    assert abs(bubble_fraction(2, 4) - 0.2) < 1e-9
    print("PASS pipeline")

else:
    raise SystemExit(f"unknown mode {MODE}")
