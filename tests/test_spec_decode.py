"""Speculative decoding inside the fused scan: draft-verify correctness.

Greedy output must be byte-identical with speculation on or off — the
drafter (n-gram lookup or layer-skip self-draft) only proposes tokens; the
verifier commits exactly the prefix the full model would have produced
token-by-token, rolls the cache position back past rejections, and the
engine retires the same completions. These tests gate that contract at the
serving-jits level and through the ServeEngine across every KV layout
(contiguous, paged, quantized), plus direct coverage of the shared
sampling helpers the verifier reuses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.launch.steps import (logits_transform, make_serving_jits,
                                ngram_draft, sample_tokens)
from repro.models import build_model, init_params


def _build(arch, batch, prompt_len, max_len):
    cfg = get_config(arch, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, ShapeConfig("serve", max_len, batch, "decode"),
                        mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params, plan


# ---------------------------------------------------------------------------
# shared sampling helpers (used by both the sampler and the spec verifier)
# ---------------------------------------------------------------------------

def test_sample_tokens_greedy_deterministic():
    """temperature=0 is a pure argmax: same logits -> same tokens, and the
    PRNG key is ignored entirely."""
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 7, 33))
    a = sample_tokens(logits, jax.random.PRNGKey(0), temperature=0.0)
    b = sample_tokens(logits, jax.random.PRNGKey(12345), temperature=0.0)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a),
                                  np.asarray(jnp.argmax(logits, axis=-1)))
    assert a.shape == (4, 7) and a.dtype == jnp.int32


def test_sample_tokens_top_k_tie_boundary():
    """Exact ties at the k-th score keep every tied token eligible (the
    mask threshold is the k-th value, not a strict cut)."""
    # top_k=2 with scores [5, 5, 5, 0]: threshold is 5, so all three tied
    # tokens stay; token 3 must never appear.
    logits = jnp.asarray([[5.0, 5.0, 5.0, 0.0]])
    seen = set()
    for seed in range(24):
        s = sample_tokens(logits, jax.random.PRNGKey(seed), temperature=1.0,
                          top_k=2)
        seen.add(int(s[0]))
    assert 3 not in seen
    assert seen <= {0, 1, 2} and len(seen) > 1


def test_logits_transform_matches_sampler():
    """The factored helper is the exact distribution the sampler draws
    from: greedy over transformed logits == greedy sampling, and masked
    entries are unreachable."""
    logits = jax.random.normal(jax.random.PRNGKey(1), (2, 11))
    t = logits_transform(logits, temperature=0.7, top_k=3)
    # masking only: argmax unchanged, exactly top-3 entries survive per row
    np.testing.assert_array_equal(np.asarray(jnp.argmax(t, -1)),
                                  np.asarray(jnp.argmax(logits, -1)))
    assert int((np.asarray(t) > -1e29).sum()) == 2 * 3
    # temperature scales in fp32 without changing the ordering
    order = np.argsort(np.asarray(logits), -1)
    np.testing.assert_array_equal(
        np.argsort(np.asarray(logits_transform(logits, 2.5, 0)), -1), order)


def test_ngram_draft_prefers_latest_bigram():
    """The drafter matches on (prev, cur) bigrams, takes the most recent
    match, and proposes its continuation."""
    #                   0  1  2  3  4  5  6  7  8  9
    hist = jnp.asarray([[5, 7, 2, 9, 5, 7, 4, 1, 5, 0]])
    hist_len = jnp.asarray([9], jnp.int32)      # idx 9 not yet committed
    # next token t0=7, preceded by hist[8]=5: bigram (5, 7) occurs at
    # idx 1 and idx 5 -> the LATEST match wins -> drafts hist[6:8] = [4, 1]
    d = ngram_draft(hist, hist_len, jnp.asarray([7], jnp.int32), 2)
    np.testing.assert_array_equal(np.asarray(d), [[4, 1]])


# ---------------------------------------------------------------------------
# byte-identity at the serving-jits level (both drafters, direct scan calls)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ngram", "draft"])
def test_spec_generate_byte_identity(mode):
    """Greedy tokens from the speculative fused scan == the non-speculative
    fused scan, byte-for-byte, on a batch mixing a lookup-friendly periodic
    prompt with an adversarial random one."""
    arch, batch, prompt_len, gen, chunk, k = "pimref-100m", 2, 16, 16, 4, 3
    max_len = prompt_len + gen
    cfg, model, params, plan = _build(arch, batch, prompt_len, max_len)

    rng = np.random.default_rng(0)
    period = rng.integers(1, cfg.vocab_size, 4)
    toks = np.empty((batch, prompt_len), np.int32)
    toks[0] = np.tile(period, prompt_len // 4)             # repetitive row
    toks[1] = rng.integers(1, cfg.vocab_size, prompt_len)  # adversarial row

    prefill, gen_off, _, _ = make_serving_jits(
        model, plan, max_len=max_len, chunk=chunk, spec="off", spec_k=0)
    logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    key = jax.random.PRNGKey(0)
    outs = []
    for _ in range(gen // chunk):
        cache, tok, key, done, n_valid, out, _failed = gen_off(
            params, cache, tok, key, jnp.int32(-1))
        outs.append(np.asarray(out))
    ref = np.concatenate(outs, 1)

    prefill2, gen_sp, _, _ = make_serving_jits(
        model, plan, max_len=max_len, chunk=chunk, spec=mode, spec_k=k)
    logits, cache = prefill2(params, {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    key = jax.random.PRNGKey(0)
    hcap = prompt_len + gen + chunk * (k + 1)
    h0 = np.zeros((batch, hcap), np.int32)
    h0[:, :prompt_len] = toks
    hist, hist_len = jnp.asarray(h0), jnp.full((batch,), prompt_len,
                                               jnp.int32)
    rows = [[] for _ in range(batch)]
    accs = []
    while min(len(r) for r in rows) < gen:
        (cache, tok, key, done, n_valid, tb, hist, hist_len, acc,
         _failed) = gen_sp(
            params, cache, tok, key, jnp.int32(-1), hist, hist_len)
        n, tb = np.asarray(n_valid), np.asarray(tb)
        accs.append(np.asarray(acc))
        for r in range(batch):
            rows[r].extend(tb[r, : n[r]].tolist())
    got = np.stack([np.asarray(r[:gen]) for r in rows])
    np.testing.assert_array_equal(got, ref, err_msg=f"mode={mode}")
    live = np.concatenate(accs, 1)
    live = live[live >= 0]
    assert (live >= 1).all() and (live <= k + 1).all()
    if mode == "draft":
        # the layer-skip drafter lands some drafts even on random weights
        assert float(live.mean()) > 1.0


# ---------------------------------------------------------------------------
# engine-level identity across KV layouts (mixed queue, slot reuse)
# ---------------------------------------------------------------------------

LAYOUTS = {
    "contiguous": {},
    "paged": {"REPRO_KV_PAGES": "8"},
    "paged_q8": {"REPRO_KV_PAGES": "8", "REPRO_KV_QUANT": "int8"},
    "q8": {"REPRO_KV_QUANT": "int8"},
}


def _mixed_queue(cfg, prompt_len, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        rng.integers(3, prompt_len + 1)),
                    max_new_tokens=n)
            for i, n in enumerate(budgets)]


@pytest.mark.parametrize("layout", sorted(LAYOUTS))
def test_engine_spec_mixed_queue_identity(layout, monkeypatch):
    """ServeEngine with REPRO_SPEC_DECODE=ngram drains a mixed queue (slot
    reuse, EOS off, partial budgets) to completions byte-identical with
    speculation off, without extra dispatches — for every KV cache layout."""
    for k, v in LAYOUTS[layout].items():
        monkeypatch.setenv(k, v)
    prompt_len, max_new, chunk, slots = 8, 10, 4, 2
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      prompt_len + max_new)
    reqs = _mixed_queue(cfg, prompt_len, [3, 10, 5, 2, 7])

    base = ServeEngine(model, params, plan, slots=slots,
                       prompt_len=prompt_len, max_new=max_new, chunk=chunk,
                       spec="off")
    ref = {c.uid: c.tokens for c in base.run([Request(r.uid, r.tokens,
                                                      r.max_new_tokens)
                                              for r in reqs])}
    eng = ServeEngine(model, params, plan, slots=slots,
                      prompt_len=prompt_len, max_new=max_new, chunk=chunk,
                      spec="ngram", spec_k=3)
    comps = {c.uid: c for c in eng.run(reqs)}

    assert len(comps) == len(ref) > slots               # slots were reused
    for uid, toks in ref.items():
        np.testing.assert_array_equal(comps[uid].tokens, toks,
                                      err_msg=f"request {uid}")
    # speculation must never cost dispatches: one per chunk, same as off
    assert (eng.stats["decode_dispatches"]
            <= base.stats["decode_dispatches"])
    assert eng.stats["spec_draft_iters"] > 0
    assert sum(eng.stats["spec_accept_hist"]) == eng.stats["spec_draft_iters"]


def test_engine_spec_draft_acceptance(monkeypatch):
    """Layer-skip self-drafting accepts real drafts (accepted_len/draft
    strictly above the 1.0 no-speculation floor) while staying greedy
    byte-identical and saving whole-chunk dispatches."""
    prompt_len, max_new, chunk, slots = 8, 10, 4, 2
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      prompt_len + max_new)
    reqs = _mixed_queue(cfg, prompt_len, [3, 10, 5, 2, 7])

    base = ServeEngine(model, params, plan, slots=slots,
                       prompt_len=prompt_len, max_new=max_new, chunk=chunk,
                       spec="off")
    ref = {c.uid: c.tokens for c in base.run([Request(r.uid, r.tokens,
                                                      r.max_new_tokens)
                                              for r in reqs])}
    eng = ServeEngine(model, params, plan, slots=slots,
                      prompt_len=prompt_len, max_new=max_new, chunk=chunk,
                      spec="draft", spec_k=3)
    comps = {c.uid: c for c in eng.run(reqs)}
    for uid, toks in ref.items():
        np.testing.assert_array_equal(comps[uid].tokens, toks,
                                      err_msg=f"request {uid}")
    assert eng.stats["spec_accepted_len_per_draft"] > 1.0
    assert (eng.stats["decode_dispatches"]
            <= base.stats["decode_dispatches"])


def test_spec_config_gates_unsupported():
    """Sliding-window / recurrent decode paths can't host draft-verify —
    the config helper falls back to off with a warning instead of
    mis-decoding, and rejects unknown modes outright."""
    from repro.launch.steps import spec_config

    class _Stub:
        def __init__(self, arch):
            self.cfg = get_config(arch, smoke=True)

    dense = _Stub("pimref-100m")
    assert spec_config(dense, "ngram", 3) == ("ngram", 3)
    assert spec_config(dense, "off", 3) == ("off", 0)
    with pytest.raises(ValueError):
        spec_config(dense, "bogus", 3)
    with pytest.warns(UserWarning, match="sliding"):
        assert spec_config(_Stub("mixtral-8x7b"), "ngram", 3) == ("off", 0)
    with pytest.warns(UserWarning, match="family"):
        assert spec_config(_Stub("recurrentgemma-2b"), "draft", 3) == \
            ("off", 0)
