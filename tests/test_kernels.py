"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref oracles.

All kernels run in interpret mode (CPU container; TPU is the target).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref_bh
from repro.kernels.narrow_value import (pack_int4, pack_int4_ref,
                                        required_bits, required_bits_ref,
                                        unpack_int4, unpack_int4_ref)
from repro.kernels.quant_matmul import (quant_matmul, quant_matmul_ref,
                                        quantize_weights)
from repro.kernels.rglru import rglru_scan, rglru_scan_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,T,Hq,Hkv,D", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 128, 256, 4, 1, 32),     # MQA, cross-length
    (1, 256, 256, 8, 8, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, T, Hq, Hkv, D, causal, window, dtype, rng):
    if causal and T != S:
        pytest.skip("causal requires square here")
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    G = Hq // Hkv
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kr = jnp.repeat(k, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    vr = jnp.repeat(v, G, 2).transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    ref = attention_ref_bh(qr, kr, vr, causal=causal, window=window)
    ref = ref.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_blocks(rng):
    """Result independent of block sizes."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    a = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    b = flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# ---------------------------------------------------------------------------
# quant matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (256, 512, 128),
                                   (128, 384, 256)])
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_matmul(M, K, N, bits, rng):
    ks = jax.random.split(rng, 2)
    x = jax.random.normal(ks[0], (M, K), jnp.float32)
    w = jax.random.normal(ks[1], (K, N), jnp.float32)
    codes, scales = quantize_weights(w, block_k=128, bits=bits)
    out = quant_matmul(x, codes, scales, interpret=True)
    ref = quant_matmul_ref(x, codes, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-4)
    # quantized result approximates the exact matmul within format error
    exact = np.asarray(x @ w)
    rel = np.abs(np.asarray(ref) - exact).max() / np.abs(exact).max()
    assert rel < (0.02 if bits == 8 else 0.25)


def test_quant_matmul_dtypes(rng):
    ks = jax.random.split(rng, 2)
    x = jax.random.normal(ks[0], (128, 128), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(ks[1], (128, 128), jnp.float32)
    codes, scales = quantize_weights(w)
    out = quant_matmul(x, codes, scales, interpret=True)
    ref = quant_matmul_ref(x.astype(jnp.float32), codes, scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0.5,
                               rtol=2e-2)


# ---------------------------------------------------------------------------
# narrow value
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,block", [(512, 256), (2048, 256), (1024, 128)])
def test_required_bits(n, block, rng):
    x = jax.random.randint(rng, (n,), -100000, 100000, jnp.int32)
    out = required_bits(x, block, interpret=True)
    ref = required_bits_ref(x, block)
    assert (np.asarray(out) == np.asarray(ref)).all()


def test_required_bits_narrow(rng):
    x = jnp.zeros((512,), jnp.int32).at[0].set(3)  # narrow: fits in 3 bits
    out = required_bits(x, 256, interpret=True)
    assert int(out[0]) == 3 and int(out[1]) == 1


@pytest.mark.parametrize("n", [512, 1024, 4096])
def test_int4_roundtrip(n, rng):
    v = jax.random.randint(rng, (n,), -8, 8, jnp.int32).astype(jnp.int8)
    p = pack_int4(v, interpret=True)
    assert p.shape == (n // 2,)
    u = unpack_int4(p, interpret=True)
    assert (np.asarray(u) == np.asarray(v)).all()
    assert (np.asarray(p) == np.asarray(pack_int4_ref(v))).all()
    assert (np.asarray(unpack_int4_ref(p)) == np.asarray(v)).all()


# ---------------------------------------------------------------------------
# rglru
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,T,D,bt", [(1, 256, 64, 128), (2, 512, 128, 128),
                                      (1, 128, 256, 64)])
def test_rglru_scan(B, T, D, bt, rng):
    ks = jax.random.split(rng, 2)
    a = jax.random.uniform(ks[0], (B, T, D), jnp.float32, 0.7, 0.999)
    b = jax.random.normal(ks[1], (B, T, D), jnp.float32) * 0.1
    out = rglru_scan(a, b, block_t=bt, interpret=True)
    ref = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
