"""End-to-end behaviour tests for the framework as a system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, SHAPES, SHAPES_BY_NAME, get_config,
                           param_count)


def test_all_archs_registered():
    assert len(ARCH_IDS) == 10
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        smoke = get_config(arch, smoke=True)
        assert cfg.family == smoke.family
        assert cfg.name == smoke.name


def test_assigned_dims_exact():
    """Configs carry the exact dims from the assignment block."""
    expect = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), arch


def test_moe_flags():
    mx = get_config("mixtral-8x7b")
    assert mx.num_experts == 8 and mx.experts_per_token == 2
    assert mx.attention_kind == "sliding" and mx.is_subquadratic
    k2 = get_config("kimi-k2-1t-a32b")
    assert k2.num_experts == 384 and k2.experts_per_token == 8
    # ~1T total params for kimi (paper-table scale)
    from repro.models import build_model
    from repro.models import module as mod
    n = mod.count_params(build_model(k2).param_specs())
    assert 0.5e12 < n < 1.5e12, n


def test_subquadratic_set():
    sub = {a for a in ARCH_IDS if get_config(a).is_subquadratic}
    assert sub == {"mixtral-8x7b", "recurrentgemma-2b", "xlstm-125m"}


def test_shapes_assignment():
    names = [s.name for s in SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert SHAPES_BY_NAME["train_4k"].global_batch == 256
    assert SHAPES_BY_NAME["long_500k"].seq_len == 524_288
    assert SHAPES_BY_NAME["decode_32k"].mode == "decode"


def test_mesh_function_does_not_require_512_devices():
    """Importing launch.mesh and calling helpers touches no device state."""
    from repro.launch import mesh as mesh_lib
    assert callable(mesh_lib.make_production_mesh)
    m = mesh_lib.make_local_mesh(("data",))
    assert mesh_lib.n_chips(m) >= 1


def test_input_specs_cover_all_cells():
    from repro.launch.specs import input_specs
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            for v in specs.values():
                assert isinstance(v, jax.ShapeDtypeStruct)
            if shape.mode == "decode":
                assert specs["tokens"].shape == (shape.global_batch, 1)
            if cfg.family == "vlm" and shape.mode != "decode":
                assert "patch_embeds" in specs
            if cfg.family == "audio" and shape.mode != "decode":
                assert "src_embeds" in specs


def test_cache_specs_abstract():
    """Cache stand-ins never allocate (eval_shape path) — FULL config."""
    from repro.launch.specs import cache_specs
    from repro.models import build_model
    cfg = get_config("internlm2-1.8b")
    model = build_model(cfg)
    cs = cache_specs(model, SHAPES_BY_NAME["decode_32k"])
    leaves = jax.tree_util.tree_leaves(cs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    assert cs["k"].shape == (24, 128, 32768, 8, 128)


def test_dryrun_skip_rules():
    from repro.launch.dryrun import skip_reason
    assert skip_reason(get_config("stablelm-3b"),
                       SHAPES_BY_NAME["long_500k"]) is not None
    assert skip_reason(get_config("xlstm-125m"),
                       SHAPES_BY_NAME["long_500k"]) is None
    assert skip_reason(get_config("mixtral-8x7b"),
                       SHAPES_BY_NAME["long_500k"]) is None
    for arch in ARCH_IDS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(get_config(arch), SHAPES_BY_NAME[s]) is None


def test_param_counts_sane():
    """Analytic param counts land in the advertised ballparks."""
    from repro.models import build_model
    from repro.models import module as mod
    expect = {
        "stablelm-3b": (2.0e9, 4.5e9),
        "stablelm-1.6b": (1.2e9, 2.5e9),
        "internlm2-1.8b": (1.3e9, 2.5e9),
        "deepseek-coder-33b": (28e9, 40e9),
        "mixtral-8x7b": (40e9, 52e9),
        "pixtral-12b": (10e9, 15e9),
        "xlstm-125m": (0.05e9, 0.25e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = mod.count_params(build_model(get_config(arch)).param_specs())
        assert lo < n < hi, (arch, n)
