"""Flash custom-VJP attention: forward + gradients vs the quadratic oracle,
including the static block-skip schedule (beyond-paper §Perf C1/B2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.damov import analyze_hlo
from repro.models.layers import (attention_ref, chunked_attention,
                                 flash_attention_jnp)


@pytest.mark.parametrize("win,cap", [(0, 0.0), (64, 0.0), (0, 30.0)])
@pytest.mark.parametrize("block_skip", [False, True])
def test_flash_vjp_grads_match_oracle(win, cap, block_skip, rng):
    B, S, Hq, Hkv, D = 2, 256, 4, 2, 32
    ks = jax.random.split(rng, 4)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    do = jax.random.normal(ks[3], (B, S, Hq, D))

    def f(q, k, v):
        o = chunked_attention(q, k, v, causal=True, window=win,
                              attn_softcap=cap, chunk_q=64, chunk_kv=64,
                              block_skip=block_skip)
        return (o * do).sum()

    def g(q, k, v):
        return (attention_ref(q, k, v, causal=True, window=win,
                              attn_softcap=cap) * do).sum()

    o1 = chunked_attention(q, k, v, causal=True, window=win, attn_softcap=cap,
                           chunk_q=64, chunk_kv=64, block_skip=block_skip)
    o2 = attention_ref(q, k, v, causal=True, window=win, attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_block_skip_halves_hlo_flops():
    """The §Perf C1 claim: causal skip does ~(nq+1)/2nq of the full work."""
    q = jax.ShapeDtypeStruct((1, 2048, 4, 1, 64), jnp.float32)
    kv = jax.ShapeDtypeStruct((1, 2048, 4, 64), jnp.float32)
    fl = {}
    for bs in (False, True):
        c = jax.jit(lambda a, b, d: flash_attention_jnp(
            a, b, d, True, 0, 0.0, 256, 256, bs)).lower(q, kv, kv).compile()
        fl[bs] = analyze_hlo(c.as_text()).flops
    nq = 2048 // 256
    expect = (nq + 1) / (2 * nq)
    assert fl[True] / fl[False] == pytest.approx(expect, rel=0.1)


def test_flash_lse_is_finite(rng):
    """Fully-masked rows (window smaller than chunk) stay finite."""
    q = jax.random.normal(rng, (1, 128, 2, 1, 16))
    kv = jax.random.normal(rng, (1, 128, 2, 16))
    out = flash_attention_jnp(q, kv, kv, True, 8, 0.0, 64, 64, False)
    assert bool(jnp.isfinite(out.astype(jnp.float32)).all())
    g = jax.grad(lambda q: flash_attention_jnp(
        q, kv, kv, True, 8, 0.0, 64, 64, False).sum())(q)
    assert bool(jnp.isfinite(g.astype(jnp.float32)).all())
