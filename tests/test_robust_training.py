"""Fault-tolerant training gates: guarded steps, verified checkpoints,
bitwise-identical resume, chaos determinism, supervisor restarts.

Mirrors tests/test_robust_serving.py on the training side. The central
invariants:

* the non-finite guard is *free* on clean steps (bitwise parity with the
  unguarded step) and a poisoned step passes params through unchanged;
* a torn/corrupt latest checkpoint restores from the previous one;
* an interrupted+resumed run is byte-identical to an uninterrupted one.
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointCorruptError, CheckpointManager,
                              CheckpointMismatchError, CheckpointWriteError)
from repro.configs import RunConfig, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.data import make_batch_fn
from repro.distributed import (TrainChaosConfig, TrainChaosMonkey,
                               TrainStepCrashError)
from repro.distributed.chaos import nan_grad_hook
from repro.launch import mesh as mesh_lib
from repro.launch import train as train_mod
from repro.launch.steps import make_train_step
from repro.launch.train import (TrainDivergedError, TrainSupervisor, train,
                                verify_resume_identity)
from repro.models import build_model, init_params
from repro.optim import make_optimizer

ARCH = "pimref-100m"
B, S = 4, 32


def _bytes_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(
        np.asarray(jax.device_get(x)).tobytes()
        == np.asarray(jax.device_get(y)).tobytes() for x, y in zip(la, lb))


@pytest.fixture(scope="module")
def step_env():
    cfg = get_config(ARCH, smoke=True)
    shape = ShapeConfig("t", seq_len=S, global_batch=B, mode="train")
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, shape, mesh)
    model = build_model(cfg)
    run = RunConfig(total_steps=10, microbatches=1)
    opt = make_optimizer(cfg.optimizer, run)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
        opt_state = opt.init(params)
    batch = {k: jnp.asarray(v)
             for k, v in make_batch_fn(cfg, shape, seed=0)(0).items()}
    return dict(model=model, opt=opt, plan=plan, run=run, params=params,
                opt_state=opt_state, batch=batch)


# ---------------------------------------------------------------------------
# Guarded step
# ---------------------------------------------------------------------------
def test_guard_disarmed_is_bitwise_identity(step_env):
    """Clean step through guard+hook == plain step, byte for byte — the
    guard and the compiled-in chaos hook cost nothing when disarmed."""
    e = step_env
    plain = jax.jit(make_train_step(e["model"], e["opt"], e["plan"],
                                    e["run"]))
    guarded = jax.jit(make_train_step(e["model"], e["opt"], e["plan"],
                                      e["run"], guard=True,
                                      grad_hook=nan_grad_hook))
    p0, s0, m0 = plain(e["params"], e["opt_state"], e["batch"])
    arm = jnp.asarray(0, jnp.int32)
    p1, s1, m1 = guarded(e["params"], e["opt_state"], e["batch"], arm)
    assert not bool(m1["skipped"])
    assert float(m0["loss"]) == float(m1["loss"])
    assert bool(jnp.isfinite(m1["grad_norm"]))
    assert _bytes_equal(p0, p1) and _bytes_equal(s0, s1)


def test_guard_armed_skips_update(step_env):
    """NaN-poisoned grads: the update is skipped — params and opt_state
    pass through byte-identical, and the metrics say so."""
    e = step_env
    guarded = jax.jit(make_train_step(e["model"], e["opt"], e["plan"],
                                      e["run"], guard=True,
                                      grad_hook=nan_grad_hook))
    arm = jnp.asarray(1, jnp.int32)
    p1, s1, m1 = guarded(e["params"], e["opt_state"], e["batch"], arm)
    assert bool(m1["skipped"])
    assert not bool(jnp.isfinite(m1["grad_norm"]))
    assert _bytes_equal(e["params"], p1)
    assert _bytes_equal(e["opt_state"], s1)


def test_divergence_raises_typed_error(tmp_path):
    run = RunConfig(total_steps=8, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=100)
    chaos = TrainChaosConfig(seed=1, nan_steps=list(range(8)))
    with pytest.raises(TrainDivergedError, match="consecutive non-finite"):
        train(ARCH, steps=8, batch=B, seq=S, run=run, chaos=chaos,
              max_bad_steps=3, log_every=100)


# ---------------------------------------------------------------------------
# Hardened checkpoints
# ---------------------------------------------------------------------------
def _tree():
    return {"w": np.arange(16, dtype=np.float32).reshape(4, 4),
            "n": np.asarray(7, dtype=np.int32)}


def test_dtype_mismatch_is_typed_and_names_leaf(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_save=False)
    ck.save(1, _tree())
    bad = {"w": np.zeros((4, 4), np.float32), "n": np.asarray(0, np.float32)}
    with pytest.raises(CheckpointMismatchError, match="dtype mismatch.*n"):
        ck.restore(bad)
    # typed error is still a ValueError for pre-existing handlers
    with pytest.raises(ValueError):
        ck.restore(bad)


def test_corrupt_latest_falls_back_to_previous(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_save=False)
    ck.save(1, _tree())
    ck.save(2, _tree())
    leaf = os.path.join(str(tmp_path), "step_00000002", "__w__.npy")
    assert os.path.exists(leaf)
    with open(leaf, "r+b") as f:       # flip payload bytes: CRC must catch
        f.seek(os.path.getsize(leaf) - 4)
        f.write(b"\xff\xff\xff\xff")
    with pytest.warns(UserWarning, match="torn/corrupt"):
        step, tree = ck.restore(_tree())
    assert step == 1
    assert (tree["w"] == _tree()["w"]).all()
    # an explicitly requested corrupt step never silently falls back
    with pytest.raises(CheckpointCorruptError):
        ck.restore(_tree(), step=2)


def test_torn_write_falls_back(tmp_path):
    ck = CheckpointManager(str(tmp_path), async_save=False)
    ck.save(3, _tree())
    ck.save(5, _tree())
    leaf = os.path.join(str(tmp_path), "step_00000005", "__w__.npy")
    with open(leaf, "r+b") as f:       # truncated leaf = torn write
        f.truncate(os.path.getsize(leaf) // 2)
    with pytest.warns(UserWarning, match="falling back"):
        step, _ = ck.restore(_tree())
    assert step == 3
    # with every checkpoint corrupt, the failure is typed
    leaf3 = os.path.join(str(tmp_path), "step_00000003", "__w__.npy")
    with open(leaf3, "r+b") as f:
        f.truncate(1)
    with pytest.warns(UserWarning):
        with pytest.raises(CheckpointCorruptError, match="no intact"):
            ck.restore(_tree())


def test_async_write_error_reraised_at_next_wait(tmp_path):
    fired = []

    def hook(step, key):
        if not fired:
            fired.append(step)
            raise OSError("disk on fire")

    ck = CheckpointManager(str(tmp_path), fault_hook=hook)
    ck.save(1, _tree())                # async write dies in the thread
    with pytest.raises(CheckpointWriteError, match="disk on fire"):
        ck.wait()
    ck.save(2, _tree())                # error was drained; next save works
    ck.wait()
    assert ck.latest_step() == 2


def test_overwrite_and_gc_never_expose_partial_steps(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    ck.save(1, _tree())
    ck.save(1, _tree())                # overwrite goes through the .old swap
    ck.save(2, _tree())
    ck.save(3, _tree())                # gc drops step 1 via .trash rename
    assert ck.all_steps() == [2, 3]
    # stray swap/trash/tmp dirs are never mistaken for checkpoints
    for suffix in (".tmp", ".old", ".trash"):
        os.makedirs(os.path.join(str(tmp_path), "step_00000009" + suffix),
                    exist_ok=True)
    assert ck.all_steps() == [2, 3]
    step, tree = ck.restore(_tree())
    assert step == 3 and int(tree["n"]) == 7


# ---------------------------------------------------------------------------
# Bitwise resume identity + supervisor
# ---------------------------------------------------------------------------
def test_resume_identity_contiguous(tmp_path):
    """Preempt at step 3 of 6, auto-restart, and the stitched run is
    byte-identical (losses and final params) to an uninterrupted one."""
    run = RunConfig(total_steps=6, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=2)
    res = verify_resume_identity(ARCH, steps=6, work_dir=str(tmp_path),
                                 preempt_after=3, max_restarts=2,
                                 batch=B, seq=S, run=run, log_every=100)
    assert res["restarts"] == 1
    assert res["losses_match"] and res["params_match"] and res["identical"]


def test_resume_identity_chaos_armed(tmp_path):
    """Same gate with the chaos plan armed: NaN-skip + slow step + injected
    preemption all replay deterministically across the restart."""
    run = RunConfig(total_steps=8, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=3)
    chaos = TrainChaosConfig(seed=5, nan_steps=[2], slow_steps=[1],
                             slow_ms=2.0, preempt=4)
    res = verify_resume_identity(ARCH, steps=8, work_dir=str(tmp_path),
                                 chaos=chaos, max_restarts=2,
                                 batch=B, seq=S, run=run, log_every=100)
    assert res["restarts"] == 1
    assert res["skipped_steps"] >= 1
    assert res["identical"], (res["losses_match"], res["params_match"])


def test_spike_rollback_reseeds_window(tmp_path):
    run = RunConfig(total_steps=7, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=2)
    chaos = TrainChaosConfig(seed=2, spike_steps=[4], spike_x=100.0)
    out = train(ARCH, steps=7, batch=B, seq=S, run=run,
                checkpoint_dir=str(tmp_path), chaos=chaos,
                spike_warmup=2, log_every=100)
    assert out["anomalies"] == 1 and out["rollbacks"] == 1
    assert len(out["losses"]) == 7          # rolled back, then completed
    assert np.isfinite(out["final_loss"])
    # the replayed window really was re-seeded and re-checkpointed
    mf = os.path.join(str(tmp_path), "manifest.json")
    with open(mf) as f:
        assert json.load(f)["train"]["data_salt"] == 1


def test_supervisor_bounded_restarts(tmp_path):
    """Hard crashes burn the restart budget; the supervisor re-raises once
    it is exhausted instead of looping forever."""
    run = RunConfig(total_steps=6, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=100)    # no checkpoint: restart from 0
    chaos = TrainChaosConfig(seed=3, crash_steps=[1, 2, 3])
    sup = TrainSupervisor(ARCH, checkpoint_dir=str(tmp_path), steps=6,
                          max_restarts=1, chaos=chaos, batch=B, seq=S,
                          run=run, log_every=100)
    with pytest.raises(TrainStepCrashError):
        sup.run()
    assert sup.restarts == 1
    assert len(sup.attempts) == 2
    assert all("error" in a for a in sup.attempts)


def test_chaos_plan_is_deterministic():
    cfg = TrainChaosConfig.parse("nan=2,slow=1,spike=1,preempt=9,seed=13",
                                 seed=7)
    assert cfg.seed == 13 and cfg.preempt == 9    # inline seed wins
    a = TrainChaosMonkey(cfg, total_steps=16)
    b = TrainChaosMonkey(cfg, total_steps=16)
    assert a.nan_steps == b.nan_steps and len(a.nan_steps) == 2
    assert a.slow_steps == b.slow_steps and a.spike_steps == b.spike_steps
    # fire-once: operational faults fire exactly once per monkey
    step = next(iter(a.slow_steps))
    a.cfg.slow_ms = 0.0
    a.on_step(step)
    a.on_step(step)
    assert sum(e["kind"] == "slow" for e in a.events) == 1
    with pytest.raises(ValueError, match="unknown train chaos knob"):
        TrainChaosConfig.parse("explode=1")


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------
def test_main_resume_past_end_prints_nothing_to_do(tmp_path, monkeypatch,
                                                   capsys):
    """--resume with start >= --steps used to crash formatting a None
    final_loss; now it reports cleanly."""
    run = RunConfig(total_steps=3, learning_rate=3e-4, microbatches=1,
                    checkpoint_every=2)
    train(ARCH, steps=3, batch=B, seq=S, run=run,
          checkpoint_dir=str(tmp_path), log_every=100)
    monkeypatch.setattr(sys, "argv", [
        "train", "--arch", ARCH, "--steps", "3", "--batch", str(B),
        "--seq", str(S), "--checkpoint-dir", str(tmp_path), "--resume"])
    train_mod.main()
    outp = capsys.readouterr().out
    assert "nothing to do: resumed at step 3" in outp
    assert "final loss" not in outp
