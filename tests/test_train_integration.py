"""Integration: training loop, checkpoint/restart, fault-tolerance paths."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import RunConfig, get_config
from repro.distributed import (PreemptionHandler, RestartManifest,
                               StragglerMonitor)
from repro.launch.train import train


def test_loss_decreases(tmp_path):
    out = train("pimref-100m", smoke=True, steps=40, batch=8, seq=64,
                run=RunConfig(total_steps=40, learning_rate=3e-3,
                              warmup_steps=5, microbatches=1),
                log_every=100)
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_roundtrip(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}
    ck.save(5, tree, extra={"loss": 1.0})
    ck.save(9, tree)
    assert ck.all_steps() == [5, 9]
    step, restored = ck.restore(tree)
    assert step == 9
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_retention(tmp_path):
    ck = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.zeros(3)})
    assert ck.all_steps() == [3, 4]


def test_resume_continues_exactly(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, resume 3 more."""
    run = RunConfig(total_steps=6, learning_rate=1e-3, microbatches=1,
                    checkpoint_every=3)
    full = train("pimref-100m", smoke=True, steps=6, batch=4, seq=32,
                 run=run, log_every=100)
    part1 = train("pimref-100m", smoke=True, steps=3, batch=4, seq=32,
                  run=run, checkpoint_dir=str(tmp_path), log_every=100)
    part2 = train("pimref-100m", smoke=True, steps=6, batch=4, seq=32,
                  run=run, checkpoint_dir=str(tmp_path), resume=True,
                  log_every=100)
    np.testing.assert_allclose(full["losses"][3:], part2["losses"],
                               rtol=1e-4, atol=1e-5)


def test_elastic_restore_new_sharding(tmp_path):
    """Checkpoints are layout-agnostic: restore with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    ck = CheckpointManager(str(tmp_path), async_save=False)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, tree)
    mesh = make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, restored = ck.restore(tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    assert (np.asarray(restored["w"]) == np.asarray(tree["w"])).all()


def test_preemption_checkpoint(tmp_path):
    """SIGTERM mid-run -> clean checkpoint + early exit."""
    handler_fired = {}

    class FiringMonitor(StragglerMonitor):
        def step_end(self, step):
            if step == 2 and not handler_fired:
                handler_fired["yes"] = True
                os.kill(os.getpid(), signal.SIGTERM)
            return super().step_end(step)

    import repro.launch.train as train_mod
    orig = train_mod.StragglerMonitor
    train_mod.StragglerMonitor = FiringMonitor
    try:
        run = RunConfig(total_steps=50, microbatches=1, checkpoint_every=1000)
        out = train("pimref-100m", smoke=True, steps=50, batch=4, seq=32,
                    run=run, checkpoint_dir=str(tmp_path), log_every=100)
    finally:
        train_mod.StragglerMonitor = orig
    assert len(out["losses"]) < 50          # exited early
    ck = CheckpointManager(str(tmp_path))
    assert ck.latest_step() is not None     # checkpoint was written
    m = RestartManifest.load(os.path.join(str(tmp_path), "manifest.json"))
    assert m.step == ck.latest_step()


def test_straggler_monitor_flags_slow_steps():
    import time
    mon = StragglerMonitor(threshold=5.0, warmup_steps=0)
    for i in range(4):
        mon.step_start()
        time.sleep(0.01)
        mon.step_end(i)
    mon.step_start()
    time.sleep(0.2)
    flag = mon.step_end(99)
    assert flag is not None and flag["step"] == 99


def test_serve_generates(tmp_path):
    from repro.launch.serve import serve
    out = serve("pimref-100m", smoke=True, batch=2, prompt_len=16, gen=4)
    assert out["tokens"].shape == (2, 4)
    assert (out["tokens"] >= 0).all()
