"""Paged (block-table) KV cache correctness: layout round-trips, the paged
Pallas decode kernels, and the serving engine's allocator / prefix sharing.

The paged cache must be a pure layout transform: greedy decode through paged
pools + page tables is byte-identical to the contiguous ring cache, and the
engine's mixed-length queue drain is byte-identical to per-request
references — with no silent prompt truncation.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.kernels.flash_attention import ops as fa_ops
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.launch.steps import make_serving_jits
from repro.models import build_model, init_params
from repro.models import layers as L

PS = 4  # page size used throughout


@pytest.fixture()
def paged_env(monkeypatch):
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))


def _build(arch, batch, prompt_len, max_len):
    cfg = get_config(arch, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, ShapeConfig("serve", max_len, batch, "decode"),
                         mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params, plan


# ---------------------------------------------------------------------------
# Layout unit tests
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["off", "int8", "int4"])
def test_paged_round_trip(mode):
    """ring -> pages -> gather is the identity (exactly, or through the
    quantizer for quantized pools)."""
    rng = np.random.default_rng(0)
    ring = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    paged = L.paged_from_ring(ring, page_size=PS, mode=mode)
    assert paged.page_size == PS and paged.kv_len == 16
    got = L.paged_gather(paged)
    want = ring if mode == "off" else L.maybe_kv_quantize(ring, mode)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        got, want)


def test_paged_update_writes_through_table(paged_env):
    """kv_cache_update lands the row in the pool page the table points at,
    and trash-page rows absorb writes without touching live pages."""
    cache = L.kv_cache_init((2, 8, 2, 4), jnp.float32, mode="off",
                            page_size=PS)
    new = jnp.ones((2, 1, 2, 4), jnp.float32)
    slot = jnp.asarray([1, 5], jnp.int32)         # page 0 off 1 / page 1 off 1
    upd = L.kv_cache_update(cache, new, slot)
    dense = L.paged_gather(upd)
    assert float(dense[0, 1].sum()) == 8.0 and float(dense[1, 5].sum()) == 8.0
    assert float(jnp.abs(dense).sum()) == 16.0    # nothing else written
    # retired slot 0: its table row points at the trash page, so a stale
    # write is absorbed there and its original pool pages stay intact
    trashed = L.PagedKVCache(upd.pages, upd.table.at[0].set(L.TRASH_PAGE))
    upd2 = L.kv_cache_update(trashed, 3 * new, slot)
    dense2 = L.paged_gather(L.PagedKVCache(upd2.pages, upd.table))
    np.testing.assert_array_equal(np.asarray(dense2[0]), np.asarray(dense[0]))
    assert float(dense2[1, 5].sum()) == 24.0      # live slot write landed


def test_aligned_cache_len(paged_env):
    assert L.aligned_cache_len(13) == 16
    assert L.aligned_cache_len(16) == 16
    assert L.aligned_cache_len(13, page_size=0) == 13


# ---------------------------------------------------------------------------
# Paged Pallas decode kernels
# ---------------------------------------------------------------------------
@pytest.mark.skipif(not fa_ops.paged_decode_supported(),
                    reason="no scalar-prefetch grid spec in this JAX build")
@pytest.mark.parametrize("mode", ["off", "int8", "int4"])
def test_paged_kernel_matches_dense(mode):
    """The paged kernel streaming pool pages through the table is bitwise
    equal to the dense decode kernel at the same tile size."""
    B, T, Hkv, D, Hq = 2, 32, 2, 16, 4
    rng = np.random.default_rng(0)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
    valid = jnp.asarray([20, 7], jnp.int32)
    kv_pos = jnp.where(jnp.arange(T)[None, :] < valid[:, None],
                       jnp.arange(T, dtype=jnp.int32)[None, :], -1)
    q_pos = (valid - 1)[:, None]
    pk = L.paged_from_ring(k, page_size=8, mode=mode)
    pv = L.paged_from_ring(v, page_size=8, mode=mode)
    if mode == "off":
        ref = fa_ops.flash_decode(q, k, v, q_pos, kv_pos, block_k=8,
                                  interpret=True)
        out = fa_ops.flash_decode_paged(q, pk.pages, pv.pages, pk.table,
                                        q_pos, kv_pos, interpret=True)
    else:
        qk = L.maybe_kv_quantize(k, mode)
        qv = L.maybe_kv_quantize(v, mode)
        ref = fa_ops.flash_decode_quant(q, qk.codes, qk.scale, qv.codes,
                                        qv.scale, q_pos, kv_pos, block_k=8,
                                        interpret=True)
        out = fa_ops.flash_decode_paged_quant(
            q, pk.pages.codes, pk.pages.scale, pv.pages.codes, pv.pages.scale,
            pk.table, q_pos, kv_pos, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.skipif(not fa_ops.paged_decode_supported(),
                    reason="no scalar-prefetch grid spec in this JAX build")
def test_paged_chunked_attention_backends_agree(paged_env):
    """chunked_attention's paged pallas dispatch vs its paged jnp gather
    fallback on the same PagedKVCache."""
    B, T, Hkv, D, Hq = 2, 16, 2, 8, 4
    rng = np.random.default_rng(1)
    k = L.paged_from_ring(
        jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32))
    v = L.paged_from_ring(
        jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32))
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, D)), jnp.float32)
    pos = jnp.asarray([9, 5], jnp.int32)
    kv_pos = jnp.where(jnp.arange(T)[None, :] <= pos[:, None],
                       jnp.arange(T, dtype=jnp.int32)[None, :], -1)
    a = L.chunked_attention(q, k, v, q_offset=pos, kv_positions=kv_pos,
                            impl="pallas")
    b = L.chunked_attention(q, k, v, q_offset=pos, kv_positions=kv_pos,
                            impl="jnp")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)


# ---------------------------------------------------------------------------
# Model decode: paged == contiguous
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["pimref-100m", "recurrentgemma-2b"])
def test_model_decode_paged_matches_contiguous(arch, monkeypatch):
    """Greedy prefill+decode with the paged cache reproduces the contiguous
    ring cache token-for-token (same model, same prompt)."""
    def greedy(pages):
        if pages:
            monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
        else:
            monkeypatch.delenv("REPRO_KV_PAGES", raising=False)
        cfg, model, params, plan = _build(arch, 1, 8, 16)
        toks = jnp.asarray(
            np.random.default_rng(2).integers(1, cfg.vocab_size, (1, 8)),
            jnp.int32)
        with use_plan(plan):
            logits, cache = jax.jit(
                lambda p, b: model.prefill(p, b, max_len=16))(
                    params, {"tokens": toks})
        step = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
        out = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(7):
            logits, cache = step(params, cache,
                                 jnp.asarray([[out[-1]]], jnp.int32))
            out.append(int(jnp.argmax(logits[0, -1])))
        return out

    assert greedy(pages=True) == greedy(pages=False)


# ---------------------------------------------------------------------------
# Serving engine: allocator, sharing, COW, error paths
# ---------------------------------------------------------------------------
def _reference_paged(model, params, plan, prompt, prompt_len, max_len, n_new):
    """Per-request mirror of the paged engine: right-pad to the bucket,
    full-logits prefill, greedy decode from the true prompt end."""
    n = len(prompt)
    toks = np.zeros((1, prompt_len), np.int32)
    toks[0, :n] = np.asarray(prompt, np.int32)
    prefill, _, _, _ = make_serving_jits(model, plan, max_len=max_len,
                                         chunk=4, full_logits=True)
    logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
    cache["pos"] = jnp.full((1,), n, jnp.int32)
    decode = jax.jit(lambda p, c, t: model.decode_step(p, c, t))
    out = [int(jnp.argmax(logits[0, n - 1]))]
    for _ in range(n_new - 1):
        lg, cache = decode(params, cache, jnp.asarray([[out[-1]]], jnp.int32))
        out.append(int(jnp.argmax(lg[0, -1])))
    return out


@pytest.mark.parametrize("kv_quant", ["off", "int8"])
def test_engine_paged_mixed_queue_byte_identical(kv_quant, monkeypatch):
    """Mixed-length queue (4x prompt-length spread) through the paged engine
    drains byte-identical to per-request references, including with
    int8-quantized pages, and the HBM accounting moves."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    if kv_quant != "off":
        monkeypatch.setenv("REPRO_KV_QUANT", kv_quant)
    prompt_len, max_new, chunk, slots = 8, 10, 4, 2
    max_len = prompt_len + max_new
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      max_len)
    rng = np.random.default_rng(3)
    lengths = [8, 8, 2, 3, 2, 5]                  # 4x spread
    prompts = [rng.integers(1, cfg.vocab_size, size=l).astype(np.int32)
               for l in lengths]
    prompts[1][:PS] = prompts[0][:PS]             # concurrent shared prefix

    eng = ServeEngine(model, params, plan, slots=slots, prompt_len=prompt_len,
                      max_new=max_new, chunk=chunk)
    assert eng.paged
    comps = {c.uid: c for c in eng.run(
        [Request(uid=i, tokens=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)])}
    for i, p in enumerate(prompts):
        ref = _reference_paged(model, params, plan, p, prompt_len, max_len,
                               min(max_new, max_len - len(p)))
        assert comps[i].tokens.tolist() == ref, f"request {i} diverged"
    assert eng.stats["prefix_hits"] > 0
    assert eng.stats["prefills"] == len(prompts)
    # HBM accounting: peak pages strictly under the contiguous worst case
    assert 0 < eng.stats["kv_pages_peak"] < slots * eng.n_logical_pages
    assert eng.stats["kv_bytes_per_token"] > 0
    assert eng.stats["kv_pages_in_use"] == 0      # fully drained
    sz = eng.compile_cache_size()
    assert sz in (None, 1)


def test_engine_paged_cow_on_ring_wrap(monkeypatch):
    """Two slots share prefix pages, then one ring-wraps into the shared
    page inside its final over-run chunk: copy-on-write must fork the page
    so the other slot's output still matches its reference."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    prompt_len, max_new, chunk, slots = 8, 4, 8, 2
    max_len = prompt_len + max_new                # T == 12: wrap in chunk 1
    cfg, model, params, plan = _build("pimref-100m", slots, prompt_len,
                                      max_len)
    rng = np.random.default_rng(4)
    base = rng.integers(1, cfg.vocab_size, size=prompt_len).astype(np.int32)
    other = base.copy()
    other[PS:] = rng.integers(1, cfg.vocab_size, size=prompt_len - PS)

    eng = ServeEngine(model, params, plan, slots=slots, prompt_len=prompt_len,
                      max_new=max_new, chunk=chunk)
    comps = {c.uid: c for c in eng.run(
        [Request(uid=0, tokens=base, max_new_tokens=max_new),
         Request(uid=1, tokens=other, max_new_tokens=max_new)])}
    assert eng.stats["prefix_hits"] > 0           # page 0 was shared
    for uid, p in ((0, base), (1, other)):
        ref = _reference_paged(model, params, plan, p, prompt_len, max_len,
                               max_new)
        assert comps[uid].tokens.tolist() == ref, f"request {uid} diverged"


@pytest.mark.parametrize("pages", [0, PS])
def test_engine_rejects_over_long_prompt(pages, monkeypatch):
    """Over-long prompts retire with an explicit error completion in BOTH
    cache layouts — never a silent truncation — and draining continues."""
    if pages:
        monkeypatch.setenv("REPRO_KV_PAGES", str(pages))
    prompt_len, max_new = 8, 4
    cfg, model, params, plan = _build("pimref-100m", 2, prompt_len,
                                      prompt_len + max_new)
    eng = ServeEngine(model, params, plan, slots=2, prompt_len=prompt_len,
                      max_new=max_new, chunk=4)
    good = np.arange(1, 5, dtype=np.int32)
    comps = {c.uid: c for c in eng.run(
        [Request(uid=0, tokens=np.arange(1, prompt_len + 2, dtype=np.int32),
                 max_new_tokens=max_new),
         Request(uid=1, tokens=good, max_new_tokens=max_new)])}
    assert comps[0].finish_reason == "error"
    assert len(comps[0].tokens) == 0
    assert "prompt" in (comps[0].error or "")
    assert comps[1].finish_reason in ("length", "eos")
    assert len(comps[1].tokens) > 0


def test_engine_paged_pages_freed_and_reused(monkeypatch):
    """Retired slots release their pages to the free list (tables point at
    trash) and the allocator reuses them for later admissions."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    prompt_len, max_new = 8, 6
    cfg, model, params, plan = _build("pimref-100m", 1, prompt_len,
                                      prompt_len + max_new)
    eng = ServeEngine(model, params, plan, slots=1, prompt_len=prompt_len,
                      max_new=max_new, chunk=3)
    rng = np.random.default_rng(5)
    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=max_new) for i in range(3)]
    eng.run(reqs)
    assert len(eng.completions) == 3
    assert eng.stats["kv_pages_in_use"] == 0
    assert eng._alloc.used == 0
    assert not eng._alloc.registry                # no leaked registrations
    n_phys = eng.slots * eng.n_logical_pages
    assert sorted(eng._alloc.free) == list(range(1, n_phys + 1))
    np.testing.assert_array_equal(eng._host_table, 0)
