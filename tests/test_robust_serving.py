"""Fault-tolerant serving: admission control, deadlines, chaos injection,
graceful degradation, and checkpoint/restore.

The contract under test: every submitted request ends in exactly one
Completion (success or a typed error ``reason``), faults quarantine only the
request they hit, and every fault-free completion is byte-identical to a
fault-free drain — across the contiguous, paged, quantized-paged, and
speculative configurations.
"""
import jax
import numpy as np
import pytest

from repro.configs import ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.distributed.chaos import ChaosConfig, TransientStepError
from repro.launch import mesh as mesh_lib
from repro.launch.engine import (ErrorReason, PagePoolExhaustedError, Request,
                                 ServeEngine, _PageAllocator)
from repro.models import build_model, init_params

PS = 4          # page size for paged configurations
ARCH = "pimref-100m"


def _build(slots, prompt_len, max_len):
    cfg = get_config(ARCH, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, ShapeConfig("serve", max_len, slots, "decode"),
                         mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params, plan


def _engine(slots=2, prompt_len=8, max_new=8, chunk=4, **kw):
    cfg, model, params, plan = _build(slots, prompt_len, prompt_len + max_new)
    return cfg, ServeEngine(model, params, plan, slots=slots,
                            prompt_len=prompt_len, max_new=max_new,
                            chunk=chunk, **kw)


def _requests(cfg, n, prompt_len=8, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def _by_uid(eng):
    return {c.uid: c for c in eng.completions}


def _set_layout(monkeypatch, layout):
    if layout in ("paged", "paged_q8"):
        monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    if layout == "paged_q8":
        monkeypatch.setenv("REPRO_KV_QUANT", "int8")
    if layout == "spec":
        monkeypatch.setenv("REPRO_SPEC_DECODE", "ngram")


# ---------------------------------------------------------------------------
# Typed errors and chaos plumbing (no engine builds)
# ---------------------------------------------------------------------------
def test_page_allocator_typed_exhaustion():
    """The allocator raises a typed error carrying pool stats, never a bare
    IndexError from an empty free list."""
    alloc = _PageAllocator(3)                      # 2 usable (row 0 = trash)
    assert alloc.alloc() == 1 and alloc.alloc() == 2
    with pytest.raises(PagePoolExhaustedError) as ei:
        alloc.alloc("unit test")
    assert ei.value.pool_stats == {"n_phys": 3, "free": 0, "used": 2,
                                   "registered": 0}
    assert "unit test" in str(ei.value)
    alloc.decref(2)                                # freed pages allocate again
    assert alloc.alloc() == 2


def test_error_reason_enum_is_the_shared_vocabulary():
    assert {r.value for r in ErrorReason} == {
        "prompt_too_long", "bad_request", "queue_full", "deadline",
        "page_pool", "nan_logits", "step_failure", "shard_lost"}
    assert str(ErrorReason.NAN_LOGITS) == "nan_logits"


def test_chaos_config_parse_and_env(monkeypatch):
    cfg = ChaosConfig.parse("nan=1, slow=2,fail=1,pages=4,slow_ms=7", seed=9)
    assert (cfg.nan, cfg.slow, cfg.fail, cfg.pages) == (1, 2, 1, 4)
    assert cfg.slow_ms == 7.0 and cfg.seed == 9 and cfg.wants_nan
    with pytest.raises(ValueError, match="unknown chaos knob"):
        ChaosConfig.parse("bogus=1")
    monkeypatch.delenv("REPRO_CHAOS", raising=False)
    assert ChaosConfig.from_env() is None
    monkeypatch.setenv("REPRO_CHAOS", "fail=2")
    env = ChaosConfig.from_env(seed=5)
    assert env.fail == 2 and env.seed == 5 and not env.wants_nan


# ---------------------------------------------------------------------------
# Admission control and backpressure
# ---------------------------------------------------------------------------
def test_bounded_queue_rejects_with_queue_full():
    """Submissions past ``max_queue`` complete immediately with a typed
    ``queue_full`` error; accepted work still drains."""
    cfg, eng = _engine(slots=1, max_queue=1)
    reqs = _requests(cfg, 3, max_new=4)
    assert eng.submit(reqs[0]) is True             # waiting: 0 -> accepted
    assert eng.submit(reqs[1]) is False            # waiting: 1 == max_queue
    assert eng.submit(reqs[2]) is False
    eng.run()
    comps = _by_uid(eng)
    assert comps[0].finish_reason == "length" and len(comps[0].tokens) == 4
    for uid in (1, 2):
        assert comps[uid].finish_reason == "error"
        assert comps[uid].reason == "queue_full"
        assert len(comps[uid].tokens) == 0
    assert eng.stats["error_completions"] == 2
    assert sorted(comps) == [0, 1, 2]              # exactly one each


def test_paged_admission_reserves_worst_case_pages(monkeypatch):
    """A pool sized for one worst-case request serializes admissions
    (backpressure, never exhaustion) and stays byte-identical to a drain
    through a full-size pool."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    # worst_pages(n=8, cap=8) = ceil(min(8+8-1+4, 16)/4) = 4 = the whole pool
    cfg, eng = _engine(page_pool_pages=4)
    assert eng.paged and eng.n_phys_pages == 5
    reqs = _requests(cfg, 3)
    eng.run(reqs)
    comps = _by_uid(eng)
    assert all(c.finish_reason == "length" for c in comps.values())
    assert eng.stats["admission_blocked"] > 0      # slots outnumber the pool
    assert eng.stats["error_completions"] == 0     # reservation never busts
    assert eng.stats["kv_pages_in_use"] == 0
    _, ref = _engine()                             # default full-size pool
    ref.run(_requests(cfg, 3))
    for uid, c in _by_uid(ref).items():
        assert comps[uid].tokens.tolist() == c.tokens.tolist(), uid


def test_paged_oversized_request_fails_fast(monkeypatch):
    """A request whose worst-case page demand exceeds the whole pool errors
    immediately (typed ``page_pool``) instead of deadlocking admission."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    cfg, eng = _engine(page_pool_pages=3)          # capacity 3 < need 4
    eng.run(_requests(cfg, 1))
    (c,) = eng.completions
    assert c.finish_reason == "error" and c.reason == "page_pool"
    assert "pool holds 3" in c.error


def test_chaos_page_steal_hits_typed_exhaustion(monkeypatch):
    """External page pressure (chaos stealing the free list) surfaces as a
    typed ``page_pool`` error on the request that needed the pages — the
    rest of the queue drains normally."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    chaos = ChaosConfig(seed=0, pages=99, steal_after_chunk=1)
    cfg, eng = _engine(slots=1, chaos=chaos)
    eng.run(_requests(cfg, 2))
    comps = _by_uid(eng)
    assert comps[0].finish_reason == "length"      # admitted before the steal
    assert comps[1].finish_reason == "error"
    assert comps[1].reason == "page_pool"
    assert "exhausted" in comps[1].error
    assert any(e["kind"] == "pages" for e in eng.chaos_events)


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["contig", "paged"])
def test_deadline_retires_queued_and_active(layout, monkeypatch):
    """With an injected clock: an expired active request returns its partial
    tokens with a ``deadline`` error, an expired queued request returns
    empty, and deadline-free survivors are byte-identical to a fault-free
    drain."""
    _set_layout(monkeypatch, layout)
    clk = {"t": 100.0}
    cfg, eng = _engine(slots=1, clock=lambda: clk["t"])
    reqs = _requests(cfg, 3)
    reqs[0].deadline_ms = 1000.0
    reqs[1].deadline_ms = 1000.0
    for r in reqs:
        eng.submit(r)
    assert eng.step()                              # admit uid 0, one chunk
    assert len(eng._active) == 1
    clk["t"] += 10.0                               # both deadlines expire
    eng.run()
    comps = _by_uid(eng)
    assert comps[0].finish_reason == "error" and comps[0].reason == "deadline"
    assert 0 < len(comps[0].tokens) < 8            # partial: one chunk's worth
    assert comps[1].finish_reason == "error" and comps[1].reason == "deadline"
    assert len(comps[1].tokens) == 0               # expired while queued
    assert comps[2].finish_reason == "length"      # no deadline: unaffected
    assert eng.stats["deadline_miss"] == 2
    if eng.paged:
        assert eng.stats["kv_pages_in_use"] == 0   # expiry freed the pages
    _, ref = _engine(slots=1)
    ref.run([_requests(cfg, 3)[2]])
    assert comps[2].tokens.tolist() == _by_uid(ref)[2].tokens.tolist()


# ---------------------------------------------------------------------------
# NaN quarantine (on-device finite guard)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["contig", "paged", "paged_q8", "spec"])
def test_nan_quarantine_is_per_slot(layout, monkeypatch):
    """Poisoned logits quarantine exactly the slot they hit: the victim
    returns the clean ``g+1``-token prefix with a ``nan_logits`` error, and
    every co-resident request decodes byte-identically to a fault-free
    drain — in all four cache/drafter configurations."""
    _set_layout(monkeypatch, layout)
    g = 2                                          # poison generated token g
    chaos = ChaosConfig(seed=0, nan_targets={1: g})
    cfg, eng = _engine(chaos=chaos)
    eng.run(_requests(cfg, 3))
    comps = _by_uid(eng)
    _, ref = _engine()
    ref.run(_requests(cfg, 3))
    refs = _by_uid(ref)
    bad = comps[1]
    assert bad.finish_reason == "error" and bad.reason == "nan_logits"
    assert len(bad.tokens) == g + 1
    assert bad.tokens.tolist() == refs[1].tokens.tolist()[:g + 1]
    for uid in (0, 2):
        assert comps[uid].finish_reason == refs[uid].finish_reason
        assert comps[uid].tokens.tolist() == refs[uid].tokens.tolist(), uid
    assert eng.chaos_events == [
        {"kind": "nan", "uid": 1,
         "pos": (eng.prompt_len if not eng.paged else 8) + g}]


# ---------------------------------------------------------------------------
# Transient failures: retry, then fail over
# ---------------------------------------------------------------------------
def test_transient_failure_retries_to_identity():
    """An injected pre-dispatch failure retries with backoff and the drain
    completes byte-identical to a fault-free run."""
    chaos = ChaosConfig(seed=0, fail_chunks=[1])
    cfg, eng = _engine(chaos=chaos, retry_backoff_s=0.0)
    eng.run(_requests(cfg, 3))
    assert eng.stats["retries"] == 1
    assert eng.stats["error_completions"] == 0
    _, ref = _engine()
    ref.run(_requests(cfg, 3))
    refs = _by_uid(ref)
    for uid, c in _by_uid(eng).items():
        assert c.tokens.tolist() == refs[uid].tokens.tolist(), uid


def test_persistent_failure_fails_over_every_request():
    """When the retry budget is exhausted, every in-flight and queued
    request gets a typed ``step_failure`` completion and the engine goes
    dead — never a hang, never a lost request."""
    cfg, eng = _engine(slots=1, chaos=ChaosConfig(),
                       max_retries=1, retry_backoff_s=0.0)

    def always_fail(idx):
        raise TransientStepError(f"persistent fault at chunk {idx}")

    eng._chaos.on_chunk = always_fail
    eng.run(_requests(cfg, 3))
    comps = _by_uid(eng)
    assert sorted(comps) == [0, 1, 2]
    for c in comps.values():
        assert c.finish_reason == "error" and c.reason == "step_failure"
    assert eng.stats["retries"] == 2               # 1 retry + the final trip
    assert eng.step() is False                     # dead engine stays dead


# ---------------------------------------------------------------------------
# Watchdog + load shedding
# ---------------------------------------------------------------------------
class _FlagOn:
    """Deterministic StragglerMonitor stand-in: flags exact chunk indices
    (wall-clock EMAs are compile-time-noisy in CI)."""

    def __init__(self, steps):
        self.steps, self.flagged = set(steps), []

    def step_start(self):
        pass

    def step_end(self, idx):
        if idx in self.steps:
            self.flagged.append({"step": idx, "seconds": 1.0, "ema": 0.1})
            return self.flagged[-1]
        return None


def test_straggler_watchdog_sheds_load_byte_identically():
    """Sustained straggler flags on the chunk dispatch shed load (here:
    chunk halved, twice); the token streams are byte-identical to an unshed
    drain — shedding trades latency mechanics, never output."""
    cfg, eng = _engine(max_new=16, shed_after=1)
    eng._straggler = _FlagOn({2, 3})
    eng.run(_requests(cfg, 4, max_new=16))
    assert eng.stats["straggler_events"] == 2
    assert eng.stats["shed_events"] == 2
    assert eng._chunk_live < eng.chunk             # degraded program live
    _, ref = _engine(max_new=16)
    ref.run(_requests(cfg, 4, max_new=16))
    refs = _by_uid(ref)
    for uid, c in _by_uid(eng).items():
        assert c.finish_reason == refs[uid].finish_reason
        assert c.tokens.tolist() == refs[uid].tokens.tolist(), uid


# ---------------------------------------------------------------------------
# Checkpoint / restore
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["contig", "paged"])
def test_snapshot_restore_drains_byte_identically(layout, monkeypatch):
    """Preempt mid-drain, snapshot, restore into a fresh engine, drain: the
    union of completions is byte-identical to an uninterrupted run. Paged
    restore resumes from prompt+produced; contiguous regenerates."""
    _set_layout(monkeypatch, layout)
    cfg, eng = _engine()
    for r in _requests(cfg, 4):
        eng.submit(r)
    eng.run(stop=lambda: eng.stats["decode_dispatches"] >= 1)
    snap = eng.snapshot()
    assert snap["active"]                          # preempted mid-decode
    assert any(d["produced"] for d in snap["active"])

    # paged resume re-prefills prompt + produced: the restored engine's
    # bucket must fit the grown prompts (the CLI's restore path does the
    # same arithmetic; page positions are true, so a bigger bucket cannot
    # change surviving tokens)
    need = max(len(d["tokens"]) + len(d["produced"])
               for d in snap["queued"] + snap["active"])
    _, eng2 = _engine(prompt_len=max(8, need) if layout == "paged" else 8)
    eng2.load_snapshot(snap)
    snap2 = eng2.snapshot()
    if eng2.paged:
        # double-snapshot: a snapshot taken before resuming round-trips to
        # the original prompts/progress (resume prefixes split back out)
        entries = lambda s: sorted(
            (d["uid"], tuple(d["tokens"]), tuple(d["produced"]),
             d["max_new_tokens"])
            for d in s["queued"] + s["active"])
        assert entries(snap2) == entries(snap)
    else:
        # contiguous restore regenerates: original prompts and caps survive,
        # mid-flight progress is intentionally discarded
        assert sorted(
            (d["uid"], tuple(d["tokens"]), d["max_new_tokens"])
            for d in snap2["queued"] + snap2["active"]) == sorted(
            (d["uid"], tuple(d["tokens"]), d["max_new_tokens"])
            for d in snap["queued"] + snap["active"])
    eng2.run()
    comps = _by_uid(eng2)

    _, ref = _engine()
    ref.run(_requests(cfg, 4))
    refs = _by_uid(ref)
    assert sorted(comps) == sorted(refs)
    for uid, c in refs.items():
        assert comps[uid].finish_reason == c.finish_reason
        assert comps[uid].tokens.tolist() == c.tokens.tolist(), uid


# ---------------------------------------------------------------------------
# The invariant under everything at once
# ---------------------------------------------------------------------------
def test_exactly_one_completion_under_mixed_chaos(monkeypatch):
    """Seeded NaN + slow + transient-failure + page-steal chaos on the
    quantized paged engine: the drain terminates and every submitted uid
    ends in exactly one completion, each with a typed reason when errored."""
    monkeypatch.setenv("REPRO_KV_PAGES", str(PS))
    monkeypatch.setenv("REPRO_KV_QUANT", "int8")
    chaos = ChaosConfig(seed=11, nan=2, slow=1, fail=1, pages=2, slow_ms=1.0,
                        steal_after_chunk=2)
    cfg, eng = _engine(chaos=chaos, retry_backoff_s=0.0)
    eng.run(_requests(cfg, 6))
    uids = sorted(c.uid for c in eng.completions)
    assert uids == list(range(6))                  # exactly one each
    valid = {r.value for r in ErrorReason}
    for c in eng.completions:
        if c.finish_reason == "error":
            assert c.reason in valid and c.error
        else:
            assert c.reason is None
    assert eng.stats["error_completions"] == sum(
        c.finish_reason == "error" for c in eng.completions)
