"""Pallas flash attention (interpret) vs the jnp oracle: full parity grid.

Covers the production cells the dispatch layer routes to the kernels —
causal x window x GQA groups x softcap x decode-mask (ring cache, per-slot
positions, valid length) x odd lengths — forward and gradient, plus the
``REPRO_ATTN_IMPL`` dispatch itself end to end through a model decode step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import attn_impl, kv_quant_mode
from repro.kernels.flash_attention import flash_attention, flash_attention_bh
from repro.models.layers import (KV_ERROR_BUDGET, attention_ref,
                                 chunked_attention, flash_attention_jnp,
                                 flash_attention_pallas, kv_dequantize,
                                 kv_quantize, ring_cache_store,
                                 ring_position_ids)


def _qkv(rng, B, S, T, Hq, Hkv, D, dtype=jnp.float32):
    ks = jax.random.split(rng, 3)
    return (jax.random.normal(ks[0], (B, S, Hq, D)).astype(dtype),
            jax.random.normal(ks[1], (B, T, Hkv, D)).astype(dtype),
            jax.random.normal(ks[2], (B, T, Hkv, D)).astype(dtype))


# ---------------------------------------------------------------------------
# Forward parity grid: Pallas (interpret) vs the quadratic oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("G", [1, 2, 4])
@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 48, 0.0), (True, 0, 20.0), (False, 0, 0.0),
    (True, 48, 20.0),
])
def test_flash_forward_grid(G, causal, window, cap, rng):
    B, S, Hkv, D = 2, 128, 2, 32
    q, k, v = _qkv(rng, B, S, S, G * Hkv, Hkv, D)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window,
                        attn_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("S,T", [(100, 100), (130, 70), (1, 96)])
def test_flash_forward_odd_lengths(S, T, rng):
    """Non-block-multiple S/T: pad + slice, padded kv masked."""
    q, k, v = _qkv(rng, 1, S, T, 4, 2, 32)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_bh_odd_length_no_crash(rng):
    """flash_attention_bh: odd S/T pad+slice (was a hard assert) and the
    compat scratch helper (was a None deref without TPU pallas)."""
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 100, 32))
    k = jax.random.normal(ks[1], (2, 100, 32))
    v = jax.random.normal(ks[2], (2, 100, 32))
    out = flash_attention_bh(q, k, v, causal=True, block_q=64, block_k=64,
                             interpret=True)
    ref = attention_ref(q[:, :, None], k[:, :, None], v[:, :, None],
                        causal=True)[:, :, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 1, 128, 128, 4, 2, 64, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


# ---------------------------------------------------------------------------
# Gradients: Pallas fwd + recompute bwd vs the jnp flash path and the oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("win,cap,G", [(0, 0.0, 2), (48, 0.0, 1),
                                       (0, 20.0, 4)])
def test_flash_pallas_grads(win, cap, G, rng):
    B, S, Hkv, D = 2, 128, 2, 32
    q, k, v = _qkv(rng, B, S, S, G * Hkv, Hkv, D)
    do = jax.random.normal(jax.random.split(rng, 4)[3], q.shape)
    qg = q.reshape(B, S, Hkv, G, D)

    def f_pallas(qg, k, v):
        return (flash_attention_pallas(qg, k, v, True, win, cap, 64, 64, 0,
                                       True).reshape(q.shape) * do).sum()

    def f_jnp(qg, k, v):
        return (flash_attention_jnp(qg, k, v, True, win, cap, 64, 64, False,
                                    0).reshape(q.shape) * do).sum()

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(qg, k, v)
    gj = jax.grad(f_jnp, argnums=(0, 1, 2))(qg, k, v)
    for a, b in zip(gp, gj):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_flash_pallas_grads_odd_length_via_dispatch(rng):
    """Odd S through chunked_attention(impl=pallas): padded-row grads zero."""
    B, S, Hq, Hkv, D = 1, 100, 4, 2, 32
    q, k, v = _qkv(rng, B, S, S, Hq, Hkv, D)
    do = jax.random.normal(jax.random.split(rng, 4)[3], q.shape)

    def make(impl):
        def f(q, k, v):
            o = chunked_attention(q, k, v, causal=True, chunk_q=64,
                                  chunk_kv=64, impl=impl)
            return (o * do).sum()
        return jax.grad(f, argnums=(0, 1, 2))(q, k, v)

    def f_ref(q, k, v):
        return (attention_ref(q, k, v, causal=True) * do).sum()

    gp = make("pallas")
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


# ---------------------------------------------------------------------------
# Decode cells: ring cache, per-sequence positions, valid length, window
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("window,valid", [(0, False), (40, False), (0, True),
                                          (40, True)])
def test_flash_decode_ring_cache(window, valid, rng):
    """The serving engine's masks: ring kv layout (-1 empty slots), per-seq
    q positions, optional kv_valid_len — Pallas decode kernel vs jnp path."""
    B, Hq, Hkv, D, cache_len, total = 2, 4, 2, 32, 64, 80
    q, k, v = _qkv(rng, B, 1, total, Hq, Hkv, D)
    kc = ring_cache_store(k, total, cache_len)
    vc = ring_cache_store(v, total, cache_len)
    pos_ids = ring_position_ids(B, total, cache_len)
    pos = jnp.full((B,), total, jnp.int32)
    kw = dict(causal=True, window=window, q_offset=pos, kv_positions=pos_ids,
              chunk_kv=48)                 # 48 also exercises T % ck != 0
    if valid:
        kw["kv_valid_len"] = pos + 1
    oj = chunked_attention(q, kc, vc, impl="jnp", **kw)
    op = chunked_attention(q, kc, vc, impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5)


def test_flash_decode_cross_attention(rng):
    """Enc-dec cross-attention decode: S=1, non-causal, odd source length."""
    q, k, v = _qkv(rng, 2, 1, 48, 4, 2, 32)
    oj = chunked_attention(q, k, v, causal=False, chunk_kv=32, impl="jnp")
    op = chunked_attention(q, k, v, causal=False, chunk_kv=32, impl="pallas")
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5)


def test_flash_decode_mixed_depth_slots(rng):
    """Continuous batching: every slot at a different depth in one cache."""
    B, Hq, Hkv, D, T = 3, 4, 1, 32, 64
    q, k, v = _qkv(rng, B, 1, T, Hq, Hkv, D)
    pos = jnp.asarray([5, 33, 61], jnp.int32)
    pos_ids = jnp.where(jnp.arange(T)[None, :] <= pos[:, None],
                        jnp.arange(T, dtype=jnp.int32)[None, :], -1)
    kw = dict(causal=True, q_offset=pos, kv_positions=pos_ids, chunk_kv=32)
    oj = chunked_attention(q, k, v, impl="jnp", **kw)
    op = chunked_attention(q, k, v, impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5)


# ---------------------------------------------------------------------------
# Proteus-quantized KV cache: in-kernel dequant decode vs the bf16 oracle
# ---------------------------------------------------------------------------
# per-bits error budgets: the shared KV_ERROR_BUDGET from models/layers.py
# (also gated in benchmarks/bench_kernels.py and tabled in the README)
KV_BUDGET = KV_ERROR_BUDGET


@pytest.mark.parametrize("mode", ["int8", "int4", "auto"])
@pytest.mark.parametrize("G", [1, 2, 4])
@pytest.mark.parametrize("cell", ["ring", "valid", "odd"])
def test_flash_decode_quant_grid(mode, G, cell, rng):
    """Quant parity grid: the Pallas in-kernel-dequant decode kernel must
    match the jnp dequant fallback exactly (same dequantized operands), and
    both must track the bf16 oracle within the per-bits error budget."""
    B, Hkv, D = 2, 2, 32
    Hq = G * Hkv
    cache_len, total = (48, 60) if cell == "odd" else (64, 80)
    q, k, v = _qkv(rng, B, 1, total, Hq, Hkv, D)
    kc = ring_cache_store(k, total, cache_len)
    vc = ring_cache_store(v, total, cache_len)
    pos = jnp.full((B,), total, jnp.int32)
    kw = dict(causal=True, q_offset=pos,
              kv_positions=ring_position_ids(B, total, cache_len),
              chunk_kv=32 if cell == "odd" else 48)
    if cell == "valid":
        kw["kv_valid_len"] = pos + 1
    qk, qv = kv_quantize(kc, mode), kv_quantize(vc, mode)
    ref = chunked_attention(q, kc, vc, impl="jnp", **kw)
    oj = chunked_attention(q, qk, qv, impl="jnp", **kw)
    op = chunked_attention(q, qk, qv, impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(op), np.asarray(oj), atol=2e-5)
    assert float(np.abs(np.asarray(oj) - np.asarray(ref)).max()) \
        <= KV_BUDGET[mode]


def test_kv_quant_auto_narrow_value_detection(rng):
    """auto mode is data-aware: uniform-magnitude rows (crest ~ 1) take the
    int4 grid (codes within [-8, 7]); spiky gaussian rows need the int8
    grid — the Proteus narrow-value / DBPE behaviour."""
    flat = jnp.sign(jax.random.normal(rng, (2, 16, 2, 32)))   # |x| == 1
    qt = kv_quantize(flat, "auto")
    assert int(jnp.abs(qt.codes).max()) <= 7
    spiky = jax.random.normal(jax.random.split(rng)[0], (2, 16, 2, 32))
    qt2 = kv_quantize(spiky, "auto")
    assert int(jnp.abs(qt2.codes).max()) > 7
    # the grid choice is transparent: dequant error still tracks the input
    rt = kv_dequantize(qt, 32, jnp.float32)
    np.testing.assert_allclose(np.asarray(rt), np.asarray(flat), atol=1e-6)


def test_kv_quant_int4_roundtrip_packing(rng):
    """int4 codes are nibble-packed: half the code bytes, exact pack/unpack
    roundtrip through the shared repro.kernels.common helpers."""
    x = jax.random.normal(rng, (2, 8, 2, 32))
    qt = kv_quantize(x, "int4")
    assert qt.codes.shape == (2, 8, 2, 16) and qt.codes.dtype == jnp.int8
    rt = kv_dequantize(qt, 32, jnp.float32)
    # per-row scale bound: |err| <= scale/2 per element
    bound = np.asarray(qt.scale)[..., None] * 0.5 + 1e-6
    assert (np.abs(np.asarray(rt - x)) <= bound).all()


def test_kv_quant_mode_knob(monkeypatch):
    monkeypatch.setenv("REPRO_KV_QUANT", "int8")
    assert kv_quant_mode() == "int8"
    monkeypatch.delenv("REPRO_KV_QUANT")
    assert kv_quant_mode() == "off"
    monkeypatch.setenv("REPRO_KV_QUANT", "nope")
    with pytest.raises(ValueError):
        kv_quant_mode()


def test_kv_quant_end_to_end_decode_step(monkeypatch, rng):
    """TransformerLM prefill + decode with REPRO_KV_QUANT=int8: the decode
    logits stay close to the bf16-cache run, with zero call-site changes,
    and the off mode is bit-identical to the pre-quant path."""
    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.models.model import TransformerLM

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), rng)
    tokens = jax.random.randint(jax.random.split(rng)[0], (2, 9), 0, 64)
    outs = {}
    for mode in ("off", "int8"):
        monkeypatch.setenv("REPRO_KV_QUANT", mode)
        logits, cache = model.prefill(params, {"tokens": tokens}, max_len=16)
        step, cache = model.decode_step(
            params, cache, jnp.argmax(logits, -1).astype(jnp.int32))
        outs[mode] = np.asarray(step)
    np.testing.assert_allclose(outs["int8"], outs["off"], atol=0.1)


# ---------------------------------------------------------------------------
# Dispatch layer: REPRO_ATTN_IMPL routes every family's attention
# ---------------------------------------------------------------------------
def test_attn_impl_knob(monkeypatch):
    monkeypatch.setenv("REPRO_ATTN_IMPL", "pallas")
    assert attn_impl() == "pallas"
    monkeypatch.setenv("REPRO_ATTN_IMPL", "jnp")
    assert attn_impl() == "jnp"
    monkeypatch.setenv("REPRO_ATTN_IMPL", "auto")
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert attn_impl() == expect
    monkeypatch.setenv("REPRO_ATTN_IMPL", "nope")
    with pytest.raises(ValueError):
        attn_impl()


def test_dispatch_env_end_to_end_decode_step(monkeypatch, rng):
    """A TransformerLM prefill + decode step is bit-compatible between the
    jnp and Pallas backends, selected purely via REPRO_ATTN_IMPL — the
    serving engine's hot path with zero call-site changes."""
    from repro.configs.base import ModelConfig
    from repro.models import init_params
    from repro.models.model import TransformerLM

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    model = TransformerLM(cfg)
    params = init_params(model.param_specs(), rng)
    tokens = jax.random.randint(jax.random.split(rng)[0], (2, 9), 0, 64)
    outs = {}
    for impl in ("jnp", "pallas"):
        monkeypatch.setenv("REPRO_ATTN_IMPL", impl)
        logits, cache = model.prefill(params, {"tokens": tokens}, max_len=16)
        step, cache = model.decode_step(
            params, cache, jnp.argmax(logits, -1).astype(jnp.int32))
        outs[impl] = (np.asarray(logits), np.asarray(step))
    np.testing.assert_allclose(outs["pallas"][0], outs["jnp"][0], atol=2e-4)
    np.testing.assert_allclose(outs["pallas"][1], outs["jnp"][1], atol=2e-4)


def test_dispatch_impl_arg_overrides_env(monkeypatch, rng):
    monkeypatch.setenv("REPRO_ATTN_IMPL", "jnp")
    q, k, v = _qkv(rng, 1, 64, 64, 2, 2, 16)
    a = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_kv=32,
                          impl="pallas")
    b = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_kv=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
