"""Hypothesis property tests on system invariants.

Skipped cleanly when hypothesis is not installed (it is a dev extra, see
requirements-dev.txt) so the tier-1 suite stays green on minimal images.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="dev extra; pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import proteus
from repro.core.mimdram import plan_sharding
from repro.data.pipeline import SyntheticLMDataset, pack_documents
from repro.kernels.narrow_value.ref import (pack_int4_ref, required_bits_ref,
                                            unpack_int4_ref)

COMMON = dict(deadline=None, max_examples=25)


# ---------------------------------------------------------------------------
# Proteus representation properties
# ---------------------------------------------------------------------------
@given(st.integers(0, 2 ** 31 - 2))
@settings(**COMMON)
def test_required_bits_int_formula(v):
    bits = int(proteus.required_bits_int(jnp.array([v], jnp.int32)))
    if v == 0:
        assert bits == 1
    else:
        assert 2 ** (bits - 1) - 1 >= v        # representable
        assert bits <= 2 or 2 ** (bits - 2) - 1 < v  # minimal


@given(st.lists(st.integers(-8, 7), min_size=2, max_size=64)
       .filter(lambda l: len(l) % 2 == 0))
@settings(**COMMON)
def test_int4_pack_roundtrip_exact(vals):
    v = jnp.asarray(vals, jnp.int8)
    assert (np.asarray(unpack_int4_ref(pack_int4_ref(v)))
            == np.asarray(v)).all()


@given(st.integers(0, 6), st.sampled_from([4, 8]),
       st.sampled_from([64, 128, 256]))
@settings(**COMMON)
def test_quantize_error_bound_property(seed, bits, block):
    x = jax.random.normal(jax.random.PRNGKey(seed), (512,), jnp.float32) \
        * (10 ** (seed % 4))
    qt = proteus.quantize(x, bits=bits, block=block)
    y = proteus.dequantize(qt)
    scale = np.repeat(np.asarray(qt.scale), block)[:512]
    assert (np.abs(np.asarray(y - x)) <= scale / 2 * 1.001 + 1e-9).all()


@given(st.integers(1, 10 ** 9), st.floats(1e-6, 0.5))
@settings(**COMMON)
def test_cost_model_total_order(n, budget):
    cm = proteus.CostModel()
    rep = cm.select(n, budget)
    assert rep.rel_err <= budget or rep.name == "bf16"
    # latency must be minimal among feasible
    for r in proteus.REPRESENTATIONS:
        if r.rel_err <= budget:
            assert cm.latency(n, rep) <= cm.latency(n, r) + 1e-12


# ---------------------------------------------------------------------------
# Planner properties: every assignment divides
# ---------------------------------------------------------------------------
ARCH_DIMS = st.fixed_dictionaries({
    "num_layers": st.sampled_from([2, 4]),
    "d_model": st.sampled_from([64, 128, 192]),
    "num_heads": st.sampled_from([2, 4, 6, 7]),
    "num_kv_heads": st.sampled_from([1, 2]),
    "d_ff": st.sampled_from([128, 192, 256]),
    "vocab_size": st.sampled_from([256, 100, 512]),
})


@given(ARCH_DIMS, st.sampled_from([(8, 128), (256, 4096), (1, 1024)]))
@settings(**COMMON)
def test_planner_rules_always_divisible(dims, bs):
    if dims["num_heads"] % dims["num_kv_heads"]:
        dims["num_kv_heads"] = 1
    cfg = ModelConfig(name="t", family="dense", **dims)
    gb, seq = bs
    shape = ShapeConfig("t", seq_len=seq, global_batch=gb, mode="train")
    plan = plan_sharding(cfg, shape, None)   # mesh-free: no crash, no rules
    assert all(not v for v in plan.rules.values())
    # dimension bookkeeping (mesh-full case covered in test_distributed via
    # subprocess): rule map covers every logical axis used by models
    for axis in ("embed", "mlp", "heads", "kv", "vocab", "act_batch"):
        assert axis in plan.rules


# ---------------------------------------------------------------------------
# Data pipeline properties
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6), st.integers(0, 5))
@settings(**COMMON)
def test_batch_determinism(step, seed):
    ds1 = SyntheticLMDataset(256, 32, 4, seed=seed)
    ds2 = SyntheticLMDataset(256, 32, 4, seed=seed)
    b1, b2 = ds1.batch(step), ds2.batch(step)
    assert (b1["tokens"] == b2["tokens"]).all()
    assert b1["tokens"].shape == (4, 32)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 256).all()


@given(st.lists(st.lists(st.integers(1, 99), min_size=1, max_size=30),
                min_size=1, max_size=10),
       st.sampled_from([16, 32]))
@settings(**COMMON)
def test_pack_documents_conservation(docs, seq_len):
    rows, masks = pack_documents(docs, seq_len)
    assert rows.shape == masks.shape
    assert rows.shape[1] == seq_len
    # every in-document token position survives exactly once
    n_doc_tokens = sum(len(d) for d in docs)
    assert int(masks.sum()) == n_doc_tokens
