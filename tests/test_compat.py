"""Tests for the JAX version-compatibility layer (repro.compat).

Three families:
  * every exported symbol resolves on the installed JAX,
  * is_manual_axis / current_axis_types agree with ground truth inside and
    outside shard_map (full- and partial-manual),
  * repo hygiene: version-fragile JAX spellings appear only inside
    src/repro/compat (the rule CI also enforces).
"""
import os
import re

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# Exports resolve
# ---------------------------------------------------------------------------
def test_all_exports_resolve():
    assert compat.__all__, "compat must declare __all__"
    for name in compat.__all__:
        assert hasattr(compat, name), f"compat.{name} missing"
        assert getattr(compat, name) is not None, f"compat.{name} is None"


def _expected_version(v):
    parts = []
    for p in v.split("."):
        m = re.match(r"\d+", p)
        if m is None:
            break
        parts.append(int(m.group()))
        if m.group() != p:
            break
    return tuple(parts[:3])


def test_version_flags_consistent():
    from repro.compat import jax_compat
    assert compat.JAX_VERSION == _expected_version(jax.__version__)
    # prerelease/dev version strings parse to their release components
    assert jax_compat._parse_version("0.5.0rc1") == (0, 5, 0)
    assert jax_compat._parse_version("0.4.39rc1") == (0, 4, 39)
    assert jax_compat._parse_version("0.7.2.dev123") == (0, 7, 2)
    assert compat.HAS_NATIVE_SHARD_MAP == hasattr(jax, "shard_map")
    assert compat.HAS_NATIVE_AXIS_TYPE == hasattr(jax.sharding, "AxisType")
    assert "repro.compat" in compat.describe_support()


def test_axis_type_members():
    # the stub and the native enum both expose these three members
    for member in ("Auto", "Explicit", "Manual"):
        assert hasattr(compat.AxisType, member)


def test_make_mesh_roundtrip():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == 1
    # axis_types is accepted on every JAX (dropped when unsupported)
    mesh2 = compat.make_mesh(
        (1,), ("data",), axis_types=(compat.AxisType.Auto,))
    assert mesh2.axis_names == ("data",)


def test_tree_utils():
    tree = {"a": jnp.ones((2,)), "b": {"c": jnp.zeros((3,))}}
    leaves = compat.tree_leaves(tree)
    assert len(leaves) == 2
    doubled = compat.tree_map(lambda x: x * 2, tree)
    flat, treedef = compat.tree_flatten(doubled)
    assert compat.tree_unflatten(treedef, flat)["a"][0] == 2.0


def test_optimization_barrier_differentiable():
    # the 0.4.x upstream barrier has no differentiation rule; compat's must
    # be transparent to value_and_grad (this is what models smoke-tests need)
    def loss(x):
        y = compat.optimization_barrier(x * 3.0)
        return (y ** 2).sum()

    x = jnp.arange(1.0, 4.0)
    val, grad = jax.value_and_grad(loss)(x)
    assert float(val) == pytest.approx(float((9 * x * x).sum()))
    assert jnp.allclose(grad, 18.0 * x)
    # pytree carries (the scan-body usage) differentiate too
    val2, grads = jax.value_and_grad(
        lambda t: compat.optimization_barrier(t)["a"].sum())({"a": x})
    assert jnp.allclose(grads["a"], 1.0)


def test_pallas_entry_points():
    pl = compat.import_pallas()
    assert hasattr(pl, "pallas_call")
    compat.import_pallas_tpu()  # may be None; must not raise


# ---------------------------------------------------------------------------
# Manual-axis detection vs ground truth
# ---------------------------------------------------------------------------
def test_manual_detection_outside_shard_map():
    assert not compat.is_manual_axis()
    assert not compat.is_manual_axis("data")
    assert not compat.in_manual_context()
    assert compat.current_axis_types() == {}
    assert compat.manual_axis_names() == frozenset()


def test_manual_detection_full_manual():
    mesh = compat.make_mesh((1,), ("data",))
    seen = {}

    def body(x):
        seen["manual"] = compat.manual_axis_names()
        seen["types"] = compat.current_axis_types()
        seen["is_data"] = compat.is_manual_axis("data")
        seen["in_ctx"] = compat.in_manual_context()
        # ground truth: a Manual axis is usable by name in collectives
        seen["axis_index_ok"] = True
        _ = jax.lax.axis_index("data")
        return x

    out = compat.shard_map(body, mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"))(jnp.arange(4.0))
    assert out.shape == (4,)
    assert seen["manual"] == frozenset({"data"})
    assert seen["is_data"] and seen["in_ctx"] and seen["axis_index_ok"]
    assert seen["types"] == {"data": compat.AxisType.Manual}
    # context fully unwound afterwards
    assert not compat.in_manual_context()


def test_manual_detection_partial_manual():
    mesh = compat.make_mesh((1, 1), ("a", "b"))
    seen = {}

    def body(x):
        seen["manual"] = compat.manual_axis_names()
        seen["types"] = compat.current_axis_types()
        return jax.lax.psum(x, "a")

    fn = compat.shard_map(body, mesh=mesh, in_specs=P("a"), out_specs=P(),
                          axis_names=frozenset({"a"}), check_vma=False)
    out = jax.jit(fn)(jnp.arange(4.0))
    assert out.shape == (4,)
    if compat.HAS_PARTIAL_MANUAL_SHARD_MAP:
        assert seen["manual"] == frozenset({"a"})
        assert seen["types"] == {"a": compat.AxisType.Manual,
                                 "b": compat.AxisType.Auto}
    else:
        # 0.4.x promotes partial-manual to fully-manual (see compat docs);
        # detection reports the effective (promoted) axis types
        assert seen["manual"] == frozenset({"a", "b"})
        assert seen["types"] == {"a": compat.AxisType.Manual,
                                 "b": compat.AxisType.Manual}


def test_partial_manual_promotion_rejects_auto_axis_specs():
    if compat.HAS_PARTIAL_MANUAL_SHARD_MAP:
        pytest.skip("native partial-manual: promotion path not taken")
    mesh = compat.make_mesh((1, 1), ("a", "b"))
    with pytest.raises(NotImplementedError):
        compat.shard_map(lambda x: x, mesh=mesh,
                         in_specs=P("a", "b"), out_specs=P("a", "b"),
                         axis_names=frozenset({"a"}))


def test_context_mesh_nesting():
    mesh = compat.make_mesh((1,), ("data",))
    seen = {}

    def body(x):
        ctx = compat.context_mesh()
        seen["names"] = tuple(ctx.axis_names) if ctx is not None else None
        return x

    compat.shard_map(body, mesh=mesh, in_specs=P("data"),
                     out_specs=P("data"))(jnp.arange(2.0))
    assert seen["names"] == ("data",)
    assert compat.context_mesh() is None


def test_shard_map_flag_spellings_equivalent():
    mesh = compat.make_mesh((1,), ("data",))
    x = jnp.arange(4.0)

    def body(v):
        return v * 2

    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        out = compat.shard_map(body, mesh=mesh, in_specs=P("data"),
                               out_specs=P("data"), **kw)(x)
        assert float(out.sum()) == float(x.sum()) * 2


def test_shard_map_rejects_conflicting_axis_args():
    mesh = compat.make_mesh((1, 1), ("a", "b"))
    with pytest.raises(TypeError):
        compat.shard_map(lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(),
                         axis_names=frozenset({"a"}), auto=frozenset({"b"}))
    with pytest.raises(ValueError):
        compat.shard_map(lambda x: x, mesh=mesh, in_specs=P(), out_specs=P(),
                         axis_names=frozenset({"nope"}))


# ---------------------------------------------------------------------------
# Repo hygiene: fragile spellings only inside the compat package.
# Pattern list lives in tools/check_jax_compat.py (shared with the CI lint
# job) so the two enforcement points cannot drift.
# ---------------------------------------------------------------------------
def _load_checker():
    import importlib.util

    path = os.path.join(REPO, "tools", "check_jax_compat.py")
    spec = importlib.util.spec_from_file_location("check_jax_compat", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_no_version_fragile_imports_outside_compat():
    checker = _load_checker()
    offenders = checker.find_offenders(REPO)
    assert not offenders, (
        "version-fragile JAX spellings outside repro.compat "
        "(import them from repro.compat instead):\n" + "\n".join(offenders))


def test_pallas_call_sites_import_via_compat():
    checker = _load_checker()
    offenders = checker.find_pallas_offenders(REPO)
    assert not offenders, (
        "pallas call sites must obtain entry points from repro.compat:\n"
        + "\n".join(offenders))


def test_pallas_lint_catches_direct_prefetch_grid_spec(tmp_path):
    # self-test for the paged-KV lint extension: constructing the
    # scalar-prefetch grid spec by its pltpu name must be flagged, while the
    # compat-accessor spelling the paged kernels use passes
    checker = _load_checker()
    pkg = tmp_path / "src" / "pkg"
    pkg.mkdir(parents=True)
    (pkg / "bad.py").write_text(
        "spec = PrefetchScalarGridSpec(num_scalar_prefetch=1)\n")
    offenders = checker.find_pallas_offenders(str(tmp_path))
    assert len(offenders) == 1 and "bad.py" in offenders[0]
    (pkg / "bad.py").write_text(
        "from repro.compat import import_pallas, pallas_prefetch_grid_spec\n"
        "pl = import_pallas()\n"
        "grid_spec = pallas_prefetch_grid_spec()\n"
        "fn = pl.pallas_call(None, grid_spec=grid_spec)\n")
    assert not checker.find_pallas_offenders(str(tmp_path))


def test_fleet_control_plane_stays_jax_free(tmp_path):
    """The fleet dispatcher and ServeFleet facade are pure host bookkeeping:
    no direct jax import is allowed there (version-sensitive symbols could
    only leak in through one), and the repo's own modules must pass."""
    checker = _load_checker()
    assert not checker.find_fleet_offenders(REPO), \
        checker.find_fleet_offenders(REPO)
    # self-test: a jax import in a control-plane module is flagged
    mod = tmp_path / "src" / "repro" / "distributed"
    mod.mkdir(parents=True)
    (mod / "dispatcher.py").write_text(
        "import jax\nfrom repro.distributed import fault_tolerance\n")
    offenders = checker.find_fleet_offenders(str(tmp_path))
    assert len(offenders) == 1 and "dispatcher.py:1" in offenders[0]
    (mod / "dispatcher.py").write_text(
        "from repro.distributed.fault_tolerance import HealthMonitor\n")
    assert not checker.find_fleet_offenders(str(tmp_path))


def test_pallas_prefetch_grid_spec_resolves():
    # may legitimately be None only where the TPU namespace is absent
    spec = compat.pallas_prefetch_grid_spec()
    if compat.import_pallas_tpu() is not None:
        assert spec is not None and callable(spec)


def test_pallas_vmem_scratch_resolves():
    # the helper must hand out a usable scratch allocation on every install,
    # including ones where import_pallas_tpu() returns None
    scr = compat.pallas_vmem_scratch((8, 128), jnp.float32)
    assert scr is not None
    if compat.import_pallas_tpu() is None:
        pl = compat.import_pallas()
        assert isinstance(scr, pl.MemoryRef)
