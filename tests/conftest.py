"""Test fixtures. NOTE: no XLA_FLAGS here — tests see the real 1-device
platform; multi-device behaviour is tested via subprocesses (test_distributed).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
