"""Multi-device behaviour via subprocesses (8 forced host devices), so the
main test process keeps the true 1-device platform."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "distributed_worker.py")


def _run(mode: str, timeout: int = 420) -> None:
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, WORKER, mode],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, (
        f"{mode} failed:\nSTDOUT:{proc.stdout[-3000:]}\n"
        f"STDERR:{proc.stderr[-3000:]}")
    assert f"PASS {mode}" in proc.stdout


def test_sharding_invariance():
    _run("sharding_invariance")


def test_dappa_distributed():
    _run("dappa_distributed")


def test_proteus_psum():
    _run("proteus_psum")


def test_proteus_train_step():
    _run("proteus_train_step")


def test_mini_dryrun():
    _run("mini_dryrun", timeout=560)


def test_pipeline_parallel():
    _run("pipeline")
