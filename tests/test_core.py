"""Unit tests for the four core subsystems (damov/mimdram/proteus/dappa)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES_BY_NAME, ShapeConfig, get_config
from repro.core import damov, dappa, proteus
from repro.core.mimdram import Plan, plan_sharding, vf_report
from repro.models.moe import moe_ffn, moe_ffn_ref, moe_param_specs
from repro.models import module as mod, init_params


# ---------------------------------------------------------------------------
# DAMOV: HLO analyzer
# ---------------------------------------------------------------------------
def test_analyzer_counts_scan_trip_counts():
    def f(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = damov.analyze_hlo(c.as_text())
    expect = 2 * 7 * 64 ** 3
    assert 0.95 * expect < st.flops < 1.2 * expect
    assert 7 in st.trip_counts


def test_analyzer_dot_flops_unrolled():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    c = jax.jit(f).lower(a, b).compile()
    st = damov.analyze_hlo(c.as_text())
    assert st.flops == pytest.approx(2 * 128 * 64 * 32, rel=0.01)
    assert st.n_dots == 1


def test_classify():
    assert damov.classify(1.0, 0.1, 0.1, "train")[1].startswith("MXU")
    assert damov.classify(0.1, 1.0, 0.1, "train")[1].startswith("MEM_BW")
    assert damov.classify(0.1, 1.0, 0.1, "decode")[1].startswith("LAT")
    assert damov.classify(0.1, 0.1, 1.0, "train")[1].startswith("ICI_CONT")


def test_shape_bytes_tuple():
    assert damov._shape_bytes("(f32[2,4]{1,0}, bf16[8])") == 2 * 4 * 4 + 8 * 2
    assert damov._shape_bytes("s32[]") == 4


# ---------------------------------------------------------------------------
# MIMDRAM: planner
# ---------------------------------------------------------------------------
def _fake_mesh_plan(arch, shape_name):
    # No real 512-device mesh in tests: use mesh=None rules? Planner logic is
    # mesh-driven; emulate with an abstract mesh via jax.sharding.Mesh over 1
    # device repeated is impossible — instead test the pure rule logic with a
    # mesh=None plan and the divisibility helpers directly.
    return plan_sharding(get_config(arch), SHAPES_BY_NAME[shape_name], None)


@pytest.mark.parametrize("arch", ["stablelm-3b", "deepseek-coder-33b",
                                  "mixtral-8x7b", "kimi-k2-1t-a32b"])
def test_planner_no_mesh_is_unsharded(arch):
    plan = _fake_mesh_plan(arch, "train_4k")
    for axes in plan.rules.values():
        assert not axes  # nothing sharded without a mesh


def test_vf_report():
    vf = vf_report(get_config("mixtral-8x7b"), SHAPES_BY_NAME["train_4k"])
    assert vf["experts"] == 8 and vf["batch"] == 256


def test_plan_spec_dedups_mesh_axes():
    plan = Plan(rules={"a": ("data",), "b": ("data",)}, mesh=None)
    s = plan.spec("a", "b")
    assert s[0] == "data" and s[1] is None  # axis used once only


# ---------------------------------------------------------------------------
# Proteus: quantization + cost model
# ---------------------------------------------------------------------------
def test_quantize_error_bound(rng):
    x = jax.random.normal(rng, (1024,), jnp.float32) * 10
    qt = proteus.quantize(x, bits=8, block=256)
    y = proteus.dequantize(qt)
    scale_per_elem = np.repeat(np.asarray(qt.scale), 256)[:1024]
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err <= scale_per_elem / 2 + 1e-7).all()


def test_quantize_shapes_and_payload(rng):
    x = jax.random.normal(rng, (37, 19), jnp.float32)
    qt = proteus.quantize(x, bits=8, block=128)
    assert proteus.dequantize(qt).shape == (37, 19)
    assert qt.nbytes_payload < x.size * 4  # compressed vs fp32


def test_narrow_required_bits_int():
    assert int(proteus.required_bits_int(jnp.array([0, 0]))) == 1
    assert int(proteus.required_bits_int(jnp.array([3]))) == 3
    assert int(proteus.required_bits_int(jnp.array([-129]))) == 9


def test_cost_model_selects_narrow_for_large_payloads():
    cm = proteus.CostModel()
    big = cm.select(100_000_000, err_budget=1e-2)
    small = cm.select(1_000, err_budget=1e-2)
    assert big.bits < 16          # narrow format wins on the wire
    assert small.bits >= big.bits  # latency-oriented pick for small payloads


def test_cost_model_respects_error_budget():
    cm = proteus.CostModel()
    assert cm.select(10 ** 9, err_budget=1e-6).name == "bf16"


def test_required_bits_float_data_aware(rng):
    """Uniform-magnitude blocks admit narrower formats than spiky ones."""
    uniform = jnp.ones((1024,)) * 0.5
    spiky = jnp.ones((1024,)).at[::256].set(1e4) * 0.5
    b_uni = int(proteus.required_bits_float(uniform, block=256, rtol=1e-2))
    b_spiky = int(proteus.required_bits_float(spiky, block=256, rtol=1e-2))
    assert b_uni < b_spiky
    # uniform blocks: crest factor 1 -> the analytic minimum for rtol=1e-2
    assert b_uni == 7


def test_select_for_tensor_data_aware(rng):
    """Same size + budget, different data -> different representation."""
    cm = proteus.CostModel()
    uniform = jnp.ones((1 << 20,), jnp.float32) * 3.0
    spiky = jax.random.normal(rng, (1 << 20,), jnp.float32) ** 5
    r_uni = cm.select_for_tensor(uniform, err_budget=5e-3)
    r_spiky = cm.select_for_tensor(spiky, err_budget=5e-3)
    assert r_uni.bits < 16          # block scale absorbs the uniform range
    assert r_spiky.bits > r_uni.bits


def test_bucketize(rng):
    tree = {"a": jnp.zeros((1024, 256)), "b": jnp.zeros((8,)),
            "c": jnp.zeros((2048, 512))}
    buckets = proteus.bucketize(tree, bucket_bytes=1 << 20)
    total = sum(len(b) for b in buckets)
    assert total == 3 and len(buckets) >= 2


# ---------------------------------------------------------------------------
# DaPPA: pattern semantics (local lowering; distributed in test_distributed)
# ---------------------------------------------------------------------------
def test_dappa_map_reduce(rng):
    x = dappa.input_stream("x")
    f = dappa.compile_pipeline(x.map(lambda v: v * 2).reduce("sum"))
    xs = jnp.arange(16.0)
    assert float(f(x=xs)) == float(2 * xs.sum())


def test_dappa_zip_filter_mean(rng):
    x, y = dappa.input_stream("x"), dappa.input_stream("y")
    prod = x.zip(y).map(lambda t: t[..., 0] * t[..., 1])
    pos_mean = prod.filter(lambda v: v > 0).reduce("mean")
    f = dappa.compile_pipeline(pos_mean)
    xs = jnp.arange(-4.0, 4.0)
    ys = jnp.ones((8,)) * 2
    ref = np.asarray(xs * 2)
    assert float(f(x=xs, y=ys)) == pytest.approx(ref[ref > 0].mean())


def test_dappa_window():
    x = dappa.input_stream("x")
    f = dappa.compile_pipeline(x.window(3, lambda w: w.sum(-1)))
    xs = jnp.arange(8.0)
    out = np.asarray(f(x=xs))
    ref = np.convolve(np.arange(8.0), np.ones(3), mode="valid")
    np.testing.assert_allclose(out[:6], ref)
    assert (out[6:] == 0).all()  # masked tail filled


@pytest.mark.parametrize("w,shape", [(2, (16,)), (5, (16,)), (4, (12, 3))])
def test_dappa_window_gather_matches_stacked_shifts(w, shape):
    """The single-gather window lowering == the w-shifted-copies reference
    (old jnp.stack path), for scalar and multi-dim stream elements."""
    xs = jnp.asarray(np.random.default_rng(0).standard_normal(shape),
                     jnp.float32)
    f = dappa.compile_pipeline(
        dappa.input_stream("x").window(w, lambda win: win))
    out = np.asarray(f(x=xs))
    # reference: w explicitly materialized shifted copies, stacked on last axis
    pad = jnp.zeros((w - 1,) + xs.shape[1:], xs.dtype)
    ext = jnp.concatenate([xs, pad], axis=0)
    n = xs.shape[0]
    ref = jnp.stack([ext[i: i + n] for i in range(w)], axis=-1)
    valid = np.arange(n) <= n - w
    np.testing.assert_array_equal(out[valid],
                                  np.asarray(ref)[valid])
    assert (out[~valid] == 0).all()


# ---------------------------------------------------------------------------
# MoE: scatter implementation vs dense oracle
# ---------------------------------------------------------------------------
def test_moe_matches_dense_oracle(rng):
    cfg = get_config("mixtral-8x7b", smoke=True).replace(capacity_factor=8.0)
    specs = moe_param_specs(cfg, jnp.float32)
    p = init_params(specs, rng)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model),
                          jnp.float32)
    out = moe_ffn(cfg, p, x)
    ref = moe_ffn_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-3)
