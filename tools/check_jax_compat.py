#!/usr/bin/env python3
"""Lint: version-fragile JAX spellings may appear only inside repro.compat.

Single source of truth for the rule — tests/test_compat.py imports this
module and the CI compat-lint job runs it as a script (stdlib only, no jax
needed). Import the shimmed symbols from ``repro.compat`` instead; see
README.md for the support matrix.
"""
from __future__ import annotations

import os
import re
import sys
from typing import Iterator, List

FORBIDDEN = [
    re.compile(r"from\s+jax\s+import\s+[^#\n]*\bshard_map\b"),
    re.compile(r"\bjax\.shard_map\b"),
    re.compile(r"from\s+jax\.experimental(\.shard_map)?\s+import\s+[^#\n]*\bshard_map\b"),
    re.compile(r"\bjax\.experimental\.shard_map\b"),
    re.compile(r"\bjax\.sharding\.AxisType\b"),
    re.compile(r"from\s+jax\.sharding\s+import\s+[^#\n]*\bAxisType\b"),
    re.compile(r"\bjax\.make_mesh\b"),
    re.compile(r"\bjax\.sharding\.get_abstract_mesh\b"),
    re.compile(r"\bjax\.lax\.axis_size\b"),
    re.compile(r"\bjax\.lax\.optimization_barrier\b"),
    re.compile(r"from\s+jax\.experimental(\.pallas)?\s+import\s+[^#\n]*\bpallas\b"),
    re.compile(r"from\s+jax\.experimental\.pallas\s+import\s"),
    re.compile(r"\bjax\.experimental\.pallas\b"),
]
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")
EXEMPT = (
    os.path.join("src", "repro", "compat"),
    os.path.join("tests", "test_compat.py"),
    os.path.join("tools", "check_jax_compat.py"),
)


def _py_files(repo: str) -> Iterator[str]:
    for d in SCAN_DIRS:
        root = os.path.join(repo, d)
        if not os.path.isdir(root):
            continue
        for dirpath, _, files in os.walk(root):
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


def find_offenders(repo: str) -> List[str]:
    offenders = []
    for path in _py_files(repo):
        rel = os.path.relpath(path, repo)
        if any(rel.startswith(e) for e in EXEMPT):
            continue
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                for pat in FORBIDDEN:
                    if pat.search(line):
                        offenders.append(f"{rel}:{lineno}: {line.strip()}")
                        break
    return offenders


# Any Pallas call site (pallas_call / pl.* entry points / pltpu.* scratch)
# must obtain its pallas modules from repro.compat — the entry-point location
# is version-sensitive and the TPU namespace may be absent entirely.
#
# Note (kv-quant PR): the Proteus-quantized decode kernel
# (flash_decode_quant_fwd) and the block-sparse tile skip reuse the existing
# import_pallas()/pallas_vmem_scratch() entry points, and the deduped int4
# nibble pack/unpack helper (repro.kernels.common.pack_int4/unpack_int4) is
# pure jnp — no new version-sensitive Pallas accessor was needed. If a future
# kernel needs a NEW pl./pltpu. symbol, add it to repro.compat and extend
# _PALLAS_NAME below so this lint keeps recognising compat-imported sites.
#
# Note (paged-KV PR): the paged decode kernels build a scalar-prefetch grid
# spec (the page table rides as a prefetched scalar feeding kv BlockSpec
# index maps) — its class lives in the version-sensitive pltpu namespace, so
# it is obtained via compat's ``pallas_prefetch_grid_spec()`` accessor;
# naming ``PrefetchScalarGridSpec`` directly is flagged below.
_PALLAS_USE = re.compile(
    r"\bpallas_call\s*\(|\bpltpu\s*\.\s*\w+\s*\(|\bpl\s*\.\s*BlockSpec\s*\(|"
    r"\bPrefetchScalarGridSpec\s*\(")
# Two-part check so parenthesized multi-line imports pass: the file must
# import *something* from repro.compat AND name a pallas accessor somewhere.
_COMPAT_IMPORT = re.compile(r"from\s+repro\.compat[\w.]*\s+import\b")
_PALLAS_NAME = re.compile(
    r"\b(import_pallas|import_pallas_tpu|pallas_call|pallas_vmem_scratch|"
    r"pallas_prefetch_grid_spec)\b")


def find_pallas_offenders(repo: str) -> List[str]:
    """Files using Pallas entry points without importing them via compat."""
    offenders = []
    for path in _py_files(repo):
        rel = os.path.relpath(path, repo)
        if any(rel.startswith(e) for e in EXEMPT):
            continue
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        uses = [(lineno, line) for lineno, line in
                enumerate(text.splitlines(), 1) if _PALLAS_USE.search(line)]
        if uses and not (_COMPAT_IMPORT.search(text)
                         and _PALLAS_NAME.search(text)):
            lineno, line = uses[0]
            offenders.append(f"{rel}:{lineno}: {line.strip()} "
                             "(pallas entry points must come from repro.compat)")
    return offenders


# The fleet control plane (dispatcher + ServeFleet) must stay free of direct
# jax imports: routing/health/failover logic is pure host bookkeeping, and
# keeping jax out guarantees no version-sensitive symbol can leak in outside
# repro.compat (and that spawned mp workers pay the jax import only inside
# the worker engine, never for the facade). Engine/device work is reached
# through repro.launch.engine / repro.launch.serve instead.
_CONTROL_PLANE = (
    os.path.join("src", "repro", "distributed", "dispatcher.py"),
    os.path.join("src", "repro", "launch", "fleet.py"),
)
_JAX_IMPORT = re.compile(r"^\s*(import\s+jax\b|from\s+jax\b)")


def find_fleet_offenders(repo: str) -> List[str]:
    """Direct jax imports inside the fleet control-plane modules."""
    offenders = []
    for rel in _CONTROL_PLANE:
        path = os.path.join(repo, rel)
        if not os.path.isfile(path):
            continue
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                if _JAX_IMPORT.search(line):
                    offenders.append(
                        f"{rel}:{lineno}: {line.strip()} "
                        "(fleet control plane must not import jax directly)")
    return offenders


def main() -> int:
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    offenders = (find_offenders(repo) + find_pallas_offenders(repo)
                 + find_fleet_offenders(repo))
    if offenders:
        print("version-fragile JAX spellings outside repro.compat "
              "(import them from repro.compat instead):", file=sys.stderr)
        for line in offenders:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"compat lint clean ({len(FORBIDDEN)} patterns + pallas-site rule "
          "+ fleet control-plane rule)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
