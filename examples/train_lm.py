"""End-to-end driver: train the ~100M pimref LM with the full production
stack — planner sharding, checkpoint/restart, preemption handling, straggler
monitoring, deterministic data.

On a TPU slice this is the real pretraining driver; on this CPU container use
--steps/--seq/--batch to size the run (full config, reduced workload):

    PYTHONPATH=src python examples/train_lm.py --steps 300 --seq 256 --batch 4
"""
import argparse
import json
import os

import numpy as np

from repro.configs import RunConfig
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model (CI); default is the FULL ~100M config")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    run = RunConfig(total_steps=args.steps, learning_rate=args.lr,
                    warmup_steps=max(args.steps // 20, 5),
                    checkpoint_every=max(args.steps // 4, 25),
                    microbatches=1)
    out = train("pimref-100m", smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, run=run,
                checkpoint_dir=args.checkpoint_dir, resume=args.resume,
                log_every=max(args.steps // 20, 1))

    losses = out["losses"]
    os.makedirs("examples/outputs", exist_ok=True)
    with open("examples/outputs/train_lm_losses.json", "w") as f:
        json.dump({"losses": losses, "args": vars(args)}, f)
    k = max(len(losses) // 10, 1)
    print("\nloss curve (decile means):",
          [round(float(np.mean(losses[i:i + k])), 3)
           for i in range(0, len(losses), k)])
    print(f"tokens seen: {args.steps * args.batch * args.seq:,}")


if __name__ == "__main__":
    main()
