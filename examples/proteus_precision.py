"""Proteus dynamic-precision demo: narrow values in real gradients, and what
the data-aware runtime does with them.

    PYTHONPATH=src python examples/proteus_precision.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, ShapeConfig, get_config
from repro.core import proteus
from repro.data import make_batch_fn
from repro.launch.train import train
from repro.models import build_model


def main() -> None:
    print("training pimref tiny for 8 steps to get realistic gradients...")
    out = train("pimref-100m", smoke=True, steps=8, batch=4, seq=64,
                run=RunConfig(total_steps=8, microbatches=1), log_every=100)
    cfg = get_config("pimref-100m", smoke=True)
    model = build_model(cfg)
    batch = {k: jnp.asarray(v) for k, v in
             make_batch_fn(cfg, ShapeConfig("t", 64, 4, "train"))(1).items()}
    grads = jax.grad(lambda p: model.loss(p, batch))(out["params"])

    gflat = jnp.concatenate([g.reshape(-1) for g in
                             jax.tree_util.tree_leaves(grads)])
    print(f"\ngradient tensor: {gflat.size:,} elements, "
          f"dynamic range {float(jnp.abs(gflat).max()):.2e} / "
          f"{float(jnp.abs(gflat)[jnp.abs(gflat) > 0].min()):.2e}")

    cm = proteus.CostModel()
    for bits in (8, 4):
        qt = proteus.quantize(gflat, bits=bits, block=256)
        rec = proteus.dequantize(qt)
        rel = float(jnp.linalg.norm(rec - gflat) / jnp.linalg.norm(gflat))
        ratio = gflat.size * 4 / qt.nbytes_payload
        print(f"int{bits}: compression {ratio:.1f}x vs fp32, "
              f"rel L2 error {rel:.4f}")
    pick = cm.select(gflat.size, err_budget=5e-3)
    print(f"\ncost-model pick for a {gflat.size:,}-element cross-pod "
          f"all-reduce: {pick.name} ({pick.bits}b)")
    print("-> wire time "
          f"{cm.latency(gflat.size, pick) * 1e3:.2f} ms vs bf16 "
          f"{cm.latency(gflat.size, proteus.REPRESENTATIONS[0]) * 1e3:.2f} ms "
          "(50 GB/s inter-pod link)")


if __name__ == "__main__":
    main()
