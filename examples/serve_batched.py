"""Batched serving with a rolling request queue (continuous batching lite).

Requests arrive with different prompt lengths; the server pads them into the
batch, prefills once, then decodes all slots in lock-step, retiring slots as
they hit their token budget and refilling from the queue.

    PYTHONPATH=src python examples/serve_batched.py --arch pimref-100m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_IDS, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    max_len = args.max_prompt + args.gen
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(
        cfg, ShapeConfig("serve", max_len, args.batch, "decode"), mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(model, plan))
    decode = jax.jit(make_decode_step(model, plan), donate_argnums=(1,))

    rng = np.random.default_rng(0)
    queue = [rng.integers(1, cfg.vocab_size,
                          rng.integers(8, args.max_prompt)).astype(np.int32)
             for _ in range(args.requests)]
    done, t0 = 0, time.time()
    total_tokens = 0
    while queue:
        wave = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
        # left-pad to a common prompt length (padding attends causally only)
        plen = max(len(r) for r in wave)
        toks = np.zeros((len(wave), plen), np.int32)
        for i, r in enumerate(wave):
            toks[i, plen - len(r):] = r
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.family == "audio":
            batch["src_embeds"] = jnp.asarray(
                rng.standard_normal((len(wave), plen, cfg.d_model)), jnp.float32)
        if cfg.family == "vlm":
            P = min(cfg.num_patches, plen // 2)
            batch["tokens"] = batch["tokens"][:, : plen - P]
            batch["patch_embeds"] = jnp.asarray(
                rng.standard_normal((len(wave), P, cfg.d_model)), jnp.float32)
        logits, cache = prefill(params, batch)
        from repro.launch.serve import _grow_cache
        cache = _grow_cache(model, cache, len(wave), plen + args.gen)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs = []
        for _ in range(args.gen):
            outs.append(np.asarray(tok[:, 0]))
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        done += len(wave)
        total_tokens += len(wave) * args.gen
        print(f"wave of {len(wave)} requests done "
              f"({done}/{args.requests}); sample: "
              f"{np.stack(outs, 1)[0][:8]}")
    dt = time.time() - t0
    print(f"\n{done} requests, {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s aggregate)")


if __name__ == "__main__":
    main()
