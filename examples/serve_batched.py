"""Continuous batching with the slot-based serving engine.

Requests with different prompt lengths and token budgets stream through a
fixed set of cache slots: finished sequences are swapped out and queued
prompts prefilled into the freed slots between fused decode chunks (one jit
dispatch per ``--chunk`` tokens). The caller never touches slots, padding,
or caches — submit Requests, receive Completions.

    PYTHONPATH=src python examples/serve_batched.py --arch pimref-100m
"""
import argparse

import jax
import numpy as np

from repro.configs import ALL_IDS, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.models import build_model, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--chunk", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(
        cfg, ShapeConfig("serve", args.max_prompt + args.gen, args.slots,
                         "decode"), mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(0))

    engine = ServeEngine(model, params, plan, slots=args.slots,
                         prompt_len=args.max_prompt, max_new=args.gen,
                         chunk=args.chunk)
    rng = np.random.default_rng(0)

    def extras():
        # modality inputs for non-text families, shaped for the engine's
        # batch=1 prompt bucket
        if cfg.family == "audio":
            src = int(args.max_prompt * cfg.src_len_ratio)
            return {"src_embeds": rng.standard_normal(
                (1, src, cfg.d_model)).astype(np.float32)}
        if cfg.family == "vlm":
            P = min(cfg.num_patches, args.max_prompt // 2)
            return {"patch_embeds": rng.standard_normal(
                (1, P, cfg.d_model)).astype(np.float32)}
        return None

    reqs = [Request(uid=i,
                    tokens=rng.integers(1, cfg.vocab_size,
                                        rng.integers(8, args.max_prompt)),
                    max_new_tokens=args.gen, extras=extras())
            for i in range(args.requests)]
    for c in engine.run(reqs):
        print(f"request {c.uid}: {len(c.tokens)} tokens "
              f"({c.finish_reason}); sample: {c.tokens[:8]}")
    s = engine.stats
    print(f"\n{len(engine.completions)} requests, {s['tokens_out']} tokens "
          f"in {s['wall_seconds']:.1f}s ({s['tokens_per_second']:.1f} tok/s, "
          f"{s['dispatches_per_token']:.3f} dispatches/token)")


if __name__ == "__main__":
    main()
