"""Quickstart: train a tiny LM for 30 steps, checkpoint, then sample from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs import RunConfig
from repro.launch.serve import serve
from repro.launch.train import train


def main() -> None:
    print("=== train (pimref tiny, 30 steps) ===")
    out = train(
        "pimref-100m", smoke=True, steps=30, batch=8, seq=64,
        run=RunConfig(total_steps=30, learning_rate=3e-3, warmup_steps=5,
                      microbatches=1),
        checkpoint_dir="/tmp/repro_quickstart", log_every=10)
    print(f"loss: {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")

    print("=== serve (batched prefill + decode) ===")
    res = serve("pimref-100m", smoke=True, batch=4, prompt_len=32, gen=8)
    print(f"prefill {res['prefill_s']:.2f}s, "
          f"{res['decode_s_per_tok'] * 1e3:.0f} ms/tok, "
          f"{res['throughput_tok_s']:.1f} tok/s")
    print("generated token ids:", np.asarray(res["tokens"][0]))


if __name__ == "__main__":
    main()
