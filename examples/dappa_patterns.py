"""DaPPA pattern programming demo: PrIM-style workloads with zero plumbing.

Vector add, dot product, selection, histogram-ish reduction and moving
average — each a few lines of patterns; the compiler inserts sharding,
collectives and halo exchanges (thesis ch. 7).

    PYTHONPATH=src python examples/dappa_patterns.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dappa
from repro.launch import mesh as mesh_lib


def main() -> None:
    mesh = mesh_lib.make_local_mesh(("data",))
    x = dappa.input_stream("x")
    y = dappa.input_stream("y")

    pipeline = {
        # VA: vector add (map over zip)
        "va": x.zip(y).map(lambda t: t[..., 0] + t[..., 1]),
        # DOT: zip -> multiply -> tree reduction
        "dot": x.zip(y).map(lambda t: t[..., 0] * t[..., 1]).reduce("sum"),
        # SEL: keep positives, count them
        "sel_count": x.filter(lambda v: v > 0).reduce("count"),
        # mean of selected values
        "sel_mean": x.filter(lambda v: v > 0).reduce("mean"),
        # TS-like: moving average of 8 (halo exchange across shards)
        "mov_avg": x.window(8, lambda w: w.mean(-1)),
        # max-abs (normalization scan)
        "max_abs": x.map(jnp.abs).reduce("max"),
    }
    f = dappa.compile_pipeline(pipeline, mesh=mesh)

    n = 1 << 12
    xs = jnp.asarray(np.random.default_rng(0).standard_normal(n), jnp.float32)
    ys = jnp.ones((n,), jnp.float32)
    out = f(x=xs, y=ys)
    print(f"va[:4]       = {np.asarray(out['va'][:4])}")
    print(f"dot          = {float(out['dot']):.3f}")
    print(f"sel_count    = {float(out['sel_count']):.0f} / {n}")
    print(f"sel_mean     = {float(out['sel_mean']):.4f}")
    print(f"mov_avg[:4]  = {np.asarray(out['mov_avg'][:4])}")
    print(f"max_abs      = {float(out['max_abs']):.3f}")
    print("\nAll patterns lowered to one SPMD program "
          f"on mesh {dict(mesh.shape)} — no PartitionSpecs written.")


if __name__ == "__main__":
    main()
