"""MIMDRAM segment-utilization benchmark (thesis Fig 5.8 / 5.13 analogue).

Compares the monolithic wide-SIMD allocation (SIMDRAM analogue: one program
over the whole mesh, batch-parallel only) against MIMDRAM's fine-grained
segments (experts mapped to independent mesh segments) on:
  (i) planner-reported segment utilization for every arch x shape,
  (ii) measured wall-time of a small MoE layer: dense all-experts execution
       (every token through every expert = rigid SIMD) vs the
       capacity-routed segmented execution.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.core.mimdram import plan_sharding, vf_report
from repro.models import init_params
from repro.models.moe import moe_ffn, moe_ffn_ref, moe_param_specs


def _time(fn, *args, n=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(emit) -> None:
    # (i) planner utilization (assignment-level; no devices needed: report VF)
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            vf = vf_report(cfg, shape)
            # monolithic allocation uses only batch VF; MIMDRAM adds
            # model-side VF (experts or heads/d_ff)
            mono = min(vf["batch"], 256) / 256.0
            seg_dims = max(vf["experts"] or 0, 1)
            emit(f"mimdram_util/vf/{arch}/{shape.name}", 0,
                 f"batchVF={vf['batch']};expertVF={vf['experts']};"
                 f"headsVF={vf['heads']};mono_util_256={mono:.3f}")

    # (ii) measured: rigid-SIMD (dense all-experts) vs segmented (routed)
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        num_experts=8, experts_per_token=2, d_model=128, d_ff=256)
    p = init_params(moe_param_specs(cfg, jnp.float32), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, cfg.d_model))
    routed = jax.jit(lambda p, x: moe_ffn(cfg, p, x))
    dense = jax.jit(lambda p, x: moe_ffn_ref(cfg, p, x))
    t_r = _time(routed, p, x)
    t_d = _time(dense, p, x)
    emit("mimdram_util/moe_routed_us", t_r * 1e6,
         f"segmented (MIMD) execution, E={cfg.num_experts} k="
         f"{cfg.experts_per_token}")
    emit("mimdram_util/moe_dense_us", t_d * 1e6,
         "rigid-SIMD (all tokens x all experts, SIMDRAM analogue)")
    emit("mimdram_util/speedup", 0, f"{t_d / t_r:.2f}x from segment allocation"
         f" (ideal {cfg.num_experts / cfg.experts_per_token:.1f}x)")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
