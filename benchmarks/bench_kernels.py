"""Per-kernel benchmark: interpret-mode parity gates + analytic TPU roofline.

Wall-clock on this CPU container is meaningless for TPU kernels, so each
flash-attention cell (causal / window / GQA / softcap / decode / odd-length)
reports (a) max |pallas - oracle| on a small shape — a hard parity gate, the
bench fails if it exceeds tolerance — and (b) the analytic per-cell roofline
on the production shape: HBM bytes for the Pallas kernel (scores never leave
VMEM; kv read once per *kv* head) vs the jnp chunked path (whose per-kv-step
fp32 (m, l, acc) scan carries round-trip through HBM), arithmetic intensity,
and the resulting memory-traffic advantage. ``report.py --kernels-csv``
distills these rows into the committed ``BENCH_kernels.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.damov import HBM_BW, PEAK_FLOPS_BF16

VMEM_BYTES = 128 * 1024 * 1024  # ~128MB v5e VMEM (usable ~half)
TOL = 2e-5                      # fp32 interpret-mode parity gate


# ---------------------------------------------------------------------------
# Analytic roofline: Pallas tiling vs jnp chunked path, per production cell
# ---------------------------------------------------------------------------
def _attn_roofline(B, S, T, Hq, Hkv, D, ck, dtype_bytes=2, kv_bits=16):
    """HBM-byte model, three lowerings of the same attention cell.

    * pallas: q/out once per q head, kv once per *kv* head (GQA tiles shared
      in VMEM), scores never leave VMEM.
    * chunked (the jnp ``flash_attention_jnp`` path): same streams plus the
      per-kv-step fp32 online-softmax carries (m, l, acc) written+read by the
      lax.scan across kv chunks — the O(S*T/ck) live-fp32 term DAMOV flags
      for train/prefill. At decode (S=1) this term is tiny: chunked decode is
      already near the KV-bandwidth floor.
    * naive (score-materializing lowering — what the cell costs without any
      online-softmax structure): adds 4 HBM passes over the fp32 score/prob
      tensor. Dominant for decode on MQA/GQA caches, where the score tensor
      (per *q* head) rivals the kv stream (per *kv* head) — the decode cells'
      memory-traffic advantage lives here.

    ``kv_bits < 16`` models the Proteus-quantized KV cache the **Pallas**
    kernel dequantizes in VMEM: each (slot, head) row of D elements costs
    ``D * kv_bits / 8`` code bytes plus one fp32 scale, so the dominant
    decode stream shrinks ~2x (int8) / ~4x (int4). The chunked/naive
    lowerings (the jnp paths) dequantize the cache up front, so they still
    stream the full-width K/V through attention — the narrow codes only
    reach HBM once per cache in their dequant pass, not per read.
    """
    flops = 4 * B * S * T * Hq * D                   # qk^T + pv
    q_io = B * S * Hq * D * dtype_bytes
    out_io = B * S * Hq * D * dtype_bytes
    kv_row = (D * dtype_bytes if kv_bits == 16
              else D * kv_bits // 8 + 4)             # codes + fp32 row scale
    kv_io = 2 * B * T * Hkv * kv_row
    kv_io_full = 2 * B * T * Hkv * D * dtype_bytes   # dequantized stream
    pallas = q_io + kv_io + out_io
    # jnp paths with a quantized cache: read codes, write the dequantized
    # full-width cache, then stream it through attention
    dequant_io = 0 if kv_bits == 16 else kv_io + kv_io_full
    nk = -(-T // ck)
    carry = (B * S * Hq * D + 2 * B * S * Hq) * 4    # fp32 acc + (m, l)
    chunked = (q_io + kv_io_full + out_io + dequant_io
               + 2 * carry * nk)                     # write + read per step
    naive = (q_io + kv_io_full + out_io + dequant_io
             + 4 * B * Hq * S * T * 4)               # s, p: write + read each
    ai = flops / pallas
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    return {
        "flops": flops, "bytes_pallas": pallas, "bytes_chunked": chunked,
        "bytes_naive": naive, "traffic_x": chunked / pallas,
        "naive_x": naive / pallas, "ai": ai,
        "proj_peak": min(1.0, ai / ridge),
        "mem_s_pallas": pallas / HBM_BW, "mem_s_chunked": chunked / HBM_BW,
    }


# (name, parity-shape kwargs, production-roofline kwargs)
_PROD_PREFILL = dict(B=8, S=4096, T=4096, Hq=16, Hkv=16, D=128, ck=1024)
CELLS = [
    ("causal", dict(causal=True), _PROD_PREFILL),
    ("window", dict(causal=True, window=64), _PROD_PREFILL),
    ("gqa", dict(causal=True, Hq=8, Hkv=2),
     dict(_PROD_PREFILL, Hq=32, Hkv=8)),
    ("softcap", dict(causal=True, softcap=30.0), _PROD_PREFILL),
    ("odd_len", dict(causal=True, S=100, T=100), _PROD_PREFILL),
    # the serving engine's inner loop: 1 new token vs a 32k ring cache
    ("decode", dict(decode=True),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024)),
    # MQA decode (Griffin-style local attention ring cache): the score
    # tensor is per *q* head while kv is per *kv* head, so the
    # score-materializing lowering doubles HBM traffic vs the Pallas kernel
    ("decode_mqa", dict(decode=True, Hkv=1),
     dict(B=64, S=1, T=2048, Hq=32, Hkv=1, D=128, ck=1024)),
    # Proteus-quantized KV cache (REPRO_KV_QUANT): the decode kernel reads
    # int8 / packed-int4 codes + per-row scales and dequantizes in VMEM —
    # kv bytes/token vs the bf16 cell is the kv_tok_x column
    ("decode_q8", dict(decode=True, kv_quant="int8"),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024, kv_bits=8)),
    ("decode_q4", dict(decode=True, kv_quant="int4"),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024, kv_bits=4)),
    ("decode_mqa_q8", dict(decode=True, Hkv=1, kv_quant="int8"),
     dict(B=64, S=1, T=2048, Hq=32, Hkv=1, D=128, ck=1024, kv_bits=8)),
    ("decode_mqa_q4", dict(decode=True, Hkv=1, kv_quant="int4"),
     dict(B=64, S=1, T=2048, Hq=32, Hkv=1, D=128, ck=1024, kv_bits=4)),
    # paged KV (block tables): the paged Pallas kernel streams pool pages
    # through scalar-prefetched page-table lookups — same HBM stream as the
    # contiguous decode kernel (the int32 table is B*NP*4 bytes, noise), so
    # the roofline is the decode cell's; the parity gate is vs the jnp
    # gather fallback over the same paged cache
    ("decode_paged", dict(decode=True, paged=True),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024)),
    ("decode_paged_q8", dict(decode=True, paged=True, kv_quant="int8"),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024, kv_bits=8)),
]

# quantized-cell accuracy budget vs the bf16 oracle: the shared
# KV_ERROR_BUDGET (models/layers.py; also the pytest gate + README table).
# Imported lazily so this module stays importable without jax warm-up cost.
def _kv_budget(mode: str) -> float:
    from repro.models.layers import KV_ERROR_BUDGET
    return KV_ERROR_BUDGET[mode]


def _parity_err(spec):
    """Returns (lowering parity err, extras dict). For quantized cells the
    parity gate compares the in-kernel-dequant Pallas kernel against the jnp
    dequant fallback (same dequantized operands -> tight), and ``extras``
    carries the accuracy error vs the bf16 oracle plus the representation
    the Proteus cost model picks for the sample cache."""
    from repro.core.proteus import CostModel
    from repro.models.layers import (attention_ref, chunked_attention,
                                     kv_quantize, paged_from_ring,
                                     ring_cache_store, ring_position_ids)

    B, D = 2, 32
    S = spec.get("S", 128)
    T = spec.get("T", 128)
    Hq = spec.get("Hq", 4)
    Hkv = spec.get("Hkv", 4)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    extras = {}
    if spec.get("decode"):
        cache_len, total = 64, 96       # ring cache wrapped past one lap
        kc = ring_cache_store(k[:, :total], total, cache_len)
        vc = ring_cache_store(v[:, :total], total, cache_len)
        pos_ids = ring_position_ids(B, total, cache_len)
        pos = jnp.full((B,), total, jnp.int32)
        args = dict(causal=True, q_offset=pos, kv_positions=pos_ids,
                    chunk_kv=32)
        mode = spec.get("kv_quant")
        if mode:
            bf16 = chunked_attention(q[:, :1], kc, vc, impl="jnp", **args)
        if spec.get("paged"):
            kc = paged_from_ring(kc, page_size=32, mode=mode or "off")
            vc = paged_from_ring(vc, page_size=32, mode=mode or "off")
        elif mode:
            kc, vc = kv_quantize(kc, mode), kv_quantize(vc, mode)
        if mode:
            extras["rep"] = CostModel().select_for_tensor(
                k[:, :total], block=D, err_budget=_kv_budget(mode)).name
        out = chunked_attention(q[:, :1], kc, vc, impl="pallas", **args)
        ref = chunked_attention(q[:, :1], kc, vc, impl="jnp", **args)
        if mode:
            extras["kv_err"] = float(np.abs(
                np.asarray(ref, np.float32) - np.asarray(bf16, np.float32))
                .max())
            extras["kv_budget"] = _kv_budget(mode)
    else:
        args = dict(causal=spec.get("causal", True),
                    window=spec.get("window", 0),
                    attn_softcap=spec.get("softcap", 0.0),
                    chunk_q=64, chunk_kv=64)
        out = chunked_attention(q, k, v, impl="pallas", **args)
        ref = attention_ref(q, k, v, causal=args["causal"],
                            window=args["window"],
                            attn_softcap=args["attn_softcap"])
    err = float(np.abs(np.asarray(out, np.float32)
                       - np.asarray(ref, np.float32)).max())
    return err, extras


def run(emit) -> None:
    # flash attention: per-cell parity gate + production roofline
    failures = []
    for name, parity_spec, prod in CELLS:
        t0 = time.perf_counter()
        err, extras = _parity_err(parity_spec)
        us = (time.perf_counter() - t0) * 1e6
        ok = err <= TOL
        if not ok:
            failures.append((name, err))
        r = _attn_roofline(**prod)
        derived = (f"max_err={err:.2e};pass={ok};ai={r['ai']:.0f};"
                   f"proj_peak={100 * r['proj_peak']:.0f}%;"
                   f"bytes_pallas={r['bytes_pallas']};"
                   f"bytes_chunked={r['bytes_chunked']};"
                   f"bytes_naive={r['bytes_naive']};"
                   f"traffic_x={r['traffic_x']:.2f};"
                   f"naive_x={r['naive_x']:.2f}")
        if prod.get("kv_bits"):
            # kv bytes/token vs the bf16 cell of identical shape, and the
            # accuracy-vs-bf16 gate within the documented error budget
            bf16 = _attn_roofline(**dict(prod, kv_bits=16))
            kv_tok_x = r["bytes_pallas"] / bf16["bytes_pallas"]
            kv_ok = extras["kv_err"] <= extras["kv_budget"]
            if not kv_ok:
                failures.append((name, extras["kv_err"]))
            derived += (f";kv_tok_x={kv_tok_x:.3f};"
                        f"kv_err={extras['kv_err']:.2e};kv_pass={kv_ok};"
                        f"rep={extras['rep']}")
        emit(f"kernels/flash/{name}", us, derived)
    # quant matmul: weight-bytes reduction at the roofline
    for bits in (16, 8, 4):
        # decode GEMV regime: M=1 batch row, bandwidth-bound on weights
        d, f = 7168, 19200
        bytes_w = d * f * bits / 8
        t_mem = bytes_w / HBM_BW
        emit(f"kernels/qmm/decode_gemv_int{bits}", t_mem * 1e6,
             f"weight-stream time for {d}x{f} layer; "
             f"{16 / bits:.1f}x faster than bf16" if bits != 16 else
             f"weight-stream time for {d}x{f} layer (bf16 baseline)")
    if failures:
        raise RuntimeError(f"flash parity gate failed: {failures}")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
