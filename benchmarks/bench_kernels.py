"""Per-kernel benchmark: interpret-mode correctness + analytic TPU roofline.

Wall-clock on this CPU container is meaningless for TPU kernels, so we
report (a) correctness vs ref oracles and (b) the analytic per-tile roofline
(VMEM working set, arithmetic intensity, projected % of v5e peak) that the
BlockSpec tiling implies — the numbers the §Perf kernel substitutions use.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.damov import HBM_BW, PEAK_FLOPS_BF16

VMEM_BYTES = 128 * 1024 * 1024  # ~128MB v5e VMEM (usable ~half)


def _flash_tile_analysis(bq, bk, d, dtype_bytes=2):
    flops = 2 * bq * bk * d * 2              # qk^T + pv
    # q read + k/v reads + output write, all in HBM bytes
    hbm = (bq * d + 2 * bk * d) * dtype_bytes + bq * d * dtype_bytes
    ai = flops / hbm
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    frac = min(1.0, ai / ridge)
    vmem = (bq * d + 2 * bk * d + bq * bk) * 4 + bq * d * 4
    return flops, hbm, ai, frac, vmem


def run(emit) -> None:
    # flash attention tiles
    for (bq, bk, d) in [(128, 128, 128), (256, 512, 128), (512, 1024, 128)]:
        fl, hb, ai, frac, vmem = _flash_tile_analysis(bq, bk, d)
        emit(f"kernels/flash/tile{bq}x{bk}x{d}", 0,
             f"AI={ai:.0f}flops/B;proj_peak={100*frac:.0f}%;"
             f"VMEM={vmem/2**20:.1f}MB;fits={vmem < VMEM_BYTES//2}")
    # quant matmul: weight-bytes reduction at the roofline
    for bits in (16, 8, 4):
        # decode GEMV regime: M=1 batch row, bandwidth-bound on weights
        d, f = 7168, 19200
        bytes_w = d * f * bits / 8
        t_mem = bytes_w / HBM_BW
        emit(f"kernels/qmm/decode_gemv_int{bits}", t_mem * 1e6,
             f"weight-stream time for {d}x{f} layer; "
             f"{16 / bits:.1f}x faster than bf16" if bits != 16 else
             f"weight-stream time for {d}x{f} layer (bf16 baseline)")
    # measured interpret-mode sanity timings (correctness path only)
    from repro.kernels.flash_attention import flash_attention
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 256, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 256, 2, 64))
    out = flash_attention(q, k, v, interpret=True)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = flash_attention(q, k, v, interpret=True)
    jax.block_until_ready(out)
    emit("kernels/flash/interpret_us", (time.perf_counter() - t0) * 1e6,
         "interpret-mode validation path (CPU; not TPU perf)")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
