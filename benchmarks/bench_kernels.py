"""Per-kernel benchmark: interpret-mode parity gates + analytic TPU roofline.

Wall-clock on this CPU container is meaningless for TPU kernels, so each
flash-attention cell (causal / window / GQA / softcap / decode / odd-length)
reports (a) max |pallas - oracle| on a small shape — a hard parity gate, the
bench fails if it exceeds tolerance — and (b) the analytic per-cell roofline
on the production shape: HBM bytes for the Pallas kernel (scores never leave
VMEM; kv read once per *kv* head) vs the jnp chunked path (whose per-kv-step
fp32 (m, l, acc) scan carries round-trip through HBM), arithmetic intensity,
and the resulting memory-traffic advantage. ``report.py --kernels-csv``
distills these rows into the committed ``BENCH_kernels.json``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.damov import HBM_BW, PEAK_FLOPS_BF16

VMEM_BYTES = 128 * 1024 * 1024  # ~128MB v5e VMEM (usable ~half)
TOL = 2e-5                      # fp32 interpret-mode parity gate


# ---------------------------------------------------------------------------
# Analytic roofline: Pallas tiling vs jnp chunked path, per production cell
# ---------------------------------------------------------------------------
def _attn_roofline(B, S, T, Hq, Hkv, D, ck, dtype_bytes=2):
    """HBM-byte model, three lowerings of the same attention cell.

    * pallas: q/out once per q head, kv once per *kv* head (GQA tiles shared
      in VMEM), scores never leave VMEM.
    * chunked (the jnp ``flash_attention_jnp`` path): same streams plus the
      per-kv-step fp32 online-softmax carries (m, l, acc) written+read by the
      lax.scan across kv chunks — the O(S*T/ck) live-fp32 term DAMOV flags
      for train/prefill. At decode (S=1) this term is tiny: chunked decode is
      already near the KV-bandwidth floor.
    * naive (score-materializing lowering — what the cell costs without any
      online-softmax structure): adds 4 HBM passes over the fp32 score/prob
      tensor. Dominant for decode on MQA/GQA caches, where the score tensor
      (per *q* head) rivals the kv stream (per *kv* head) — the decode cells'
      memory-traffic advantage lives here.
    """
    flops = 4 * B * S * T * Hq * D                   # qk^T + pv
    q_io = B * S * Hq * D * dtype_bytes
    out_io = B * S * Hq * D * dtype_bytes
    kv_io = 2 * B * T * Hkv * D * dtype_bytes
    pallas = q_io + kv_io + out_io
    nk = -(-T // ck)
    carry = (B * S * Hq * D + 2 * B * S * Hq) * 4    # fp32 acc + (m, l)
    chunked = pallas + 2 * carry * nk                # write + read per step
    naive = pallas + 4 * B * Hq * S * T * 4          # s, p: write + read each
    ai = flops / pallas
    ridge = PEAK_FLOPS_BF16 / HBM_BW
    return {
        "flops": flops, "bytes_pallas": pallas, "bytes_chunked": chunked,
        "bytes_naive": naive, "traffic_x": chunked / pallas,
        "naive_x": naive / pallas, "ai": ai,
        "proj_peak": min(1.0, ai / ridge),
        "mem_s_pallas": pallas / HBM_BW, "mem_s_chunked": chunked / HBM_BW,
    }


# (name, parity-shape kwargs, production-roofline kwargs)
_PROD_PREFILL = dict(B=8, S=4096, T=4096, Hq=16, Hkv=16, D=128, ck=1024)
CELLS = [
    ("causal", dict(causal=True), _PROD_PREFILL),
    ("window", dict(causal=True, window=64), _PROD_PREFILL),
    ("gqa", dict(causal=True, Hq=8, Hkv=2),
     dict(_PROD_PREFILL, Hq=32, Hkv=8)),
    ("softcap", dict(causal=True, softcap=30.0), _PROD_PREFILL),
    ("odd_len", dict(causal=True, S=100, T=100), _PROD_PREFILL),
    # the serving engine's inner loop: 1 new token vs a 32k ring cache
    ("decode", dict(decode=True),
     dict(B=64, S=1, T=32768, Hq=32, Hkv=8, D=128, ck=1024)),
    # MQA decode (Griffin-style local attention ring cache): the score
    # tensor is per *q* head while kv is per *kv* head, so the
    # score-materializing lowering doubles HBM traffic vs the Pallas kernel
    ("decode_mqa", dict(decode=True, Hkv=1),
     dict(B=64, S=1, T=2048, Hq=32, Hkv=1, D=128, ck=1024)),
]


def _parity_err(spec) -> float:
    from repro.models.layers import (attention_ref, chunked_attention,
                                     ring_cache_store, ring_position_ids)

    B, D = 2, 32
    S = spec.get("S", 128)
    T = spec.get("T", 128)
    Hq = spec.get("Hq", 4)
    Hkv = spec.get("Hkv", 4)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, T, Hkv, D))
    v = jax.random.normal(ks[2], (B, T, Hkv, D))
    if spec.get("decode"):
        cache_len, total = 64, 96       # ring cache wrapped past one lap
        kc = ring_cache_store(k[:, :total], total, cache_len)
        vc = ring_cache_store(v[:, :total], total, cache_len)
        pos_ids = ring_position_ids(B, total, cache_len)
        pos = jnp.full((B,), total, jnp.int32)
        args = dict(causal=True, q_offset=pos, kv_positions=pos_ids,
                    chunk_kv=32)
        out = chunked_attention(q[:, :1], kc, vc, impl="pallas", **args)
        ref = chunked_attention(q[:, :1], kc, vc, impl="jnp", **args)
    else:
        args = dict(causal=spec.get("causal", True),
                    window=spec.get("window", 0),
                    attn_softcap=spec.get("softcap", 0.0),
                    chunk_q=64, chunk_kv=64)
        out = chunked_attention(q, k, v, impl="pallas", **args)
        ref = attention_ref(q, k, v, causal=args["causal"],
                            window=args["window"],
                            attn_softcap=args["attn_softcap"])
    return float(np.abs(np.asarray(out, np.float32)
                        - np.asarray(ref, np.float32)).max())


def run(emit) -> None:
    # flash attention: per-cell parity gate + production roofline
    failures = []
    for name, parity_spec, prod in CELLS:
        t0 = time.perf_counter()
        err = _parity_err(parity_spec)
        us = (time.perf_counter() - t0) * 1e6
        ok = err <= TOL
        if not ok:
            failures.append((name, err))
        r = _attn_roofline(**prod)
        emit(f"kernels/flash/{name}", us,
             f"max_err={err:.2e};pass={ok};ai={r['ai']:.0f};"
             f"proj_peak={100 * r['proj_peak']:.0f}%;"
             f"bytes_pallas={r['bytes_pallas']};"
             f"bytes_chunked={r['bytes_chunked']};"
             f"bytes_naive={r['bytes_naive']};"
             f"traffic_x={r['traffic_x']:.2f};"
             f"naive_x={r['naive_x']:.2f}")
    # quant matmul: weight-bytes reduction at the roofline
    for bits in (16, 8, 4):
        # decode GEMV regime: M=1 batch row, bandwidth-bound on weights
        d, f = 7168, 19200
        bytes_w = d * f * bits / 8
        t_mem = bytes_w / HBM_BW
        emit(f"kernels/qmm/decode_gemv_int{bits}", t_mem * 1e6,
             f"weight-stream time for {d}x{f} layer; "
             f"{16 / bits:.1f}x faster than bf16" if bits != 16 else
             f"weight-stream time for {d}x{f} layer (bf16 baseline)")
    if failures:
        raise RuntimeError(f"flash parity gate failed: {failures}")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
