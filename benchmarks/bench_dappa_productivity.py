"""DaPPA productivity + overhead benchmark (thesis Table 7.1 / Fig 7.4-7.5).

Three PrIM-style workloads implemented twice:
  (a) DaPPA patterns (map/zip/reduce/window/filter),
  (b) hand-written jnp/shard_map equivalents.
Reports lines-of-code and measured wall-time ratio.
"""
from __future__ import annotations

import inspect
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.core import dappa


# --- workload definitions ---------------------------------------------------
def dappa_dot():
    x, y = dappa.input_stream("x"), dappa.input_stream("y")
    return dappa.compile_pipeline(
        x.zip(y).map(lambda t: t[..., 0] * t[..., 1]).reduce("sum"))


def hand_dot():
    def f(x, y):
        return (x * y).sum()
    return jax.jit(f)


def dappa_select_mean():
    x = dappa.input_stream("x")
    return dappa.compile_pipeline(x.filter(lambda v: v > 0).reduce("mean"))


def hand_select_mean():
    def f(x):
        m = x > 0
        return jnp.where(m, x, 0).sum() / jnp.maximum(m.sum(), 1)
    return jax.jit(f)


def dappa_moving_max():
    x = dappa.input_stream("x")
    return dappa.compile_pipeline(x.window(8, lambda w: w.max(-1)))


def hand_moving_max():
    def f(x):
        n = x.shape[0]
        ext = jnp.concatenate([x, jnp.zeros((7,), x.dtype)])
        wins = jnp.stack([ext[i: i + n] for i in range(8)], axis=-1)
        out = wins.max(-1)
        valid = jnp.arange(n) <= n - 8
        return jnp.where(valid, out, 0)
    return jax.jit(f)


WORKLOADS = [
    ("dot_product", dappa_dot, hand_dot, ("x", "y")),
    ("select_mean", dappa_select_mean, hand_select_mean, ("x",)),
    ("moving_max", dappa_moving_max, hand_moving_max, ("x",)),
]


def _loc(fn) -> int:
    src = inspect.getsource(fn)
    return sum(1 for line in src.splitlines()
               if line.strip() and not line.strip().startswith(("#", "def",
                                                                '"""')))


def _time(fn, kwargs, n=20):
    out = fn(**kwargs)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(**kwargs)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run(emit) -> None:
    xs = jnp.linspace(-4, 4, 1 << 16)
    ys = jnp.linspace(1, 2, 1 << 16)
    env = {"x": xs, "y": ys}
    for name, mk_d, mk_h, args in WORKLOADS:
        fd, fh = mk_d(), mk_h()
        kw = {k: env[k] for k in args}
        td = _time(lambda **k: fd(**k), kw)
        th = _time(lambda **k: fh(*[k[a] for a in args]) if False else
                   fh(*(k[a] for a in args)), kw)
        # correctness cross-check
        od = np.asarray(fd(**kw))
        oh = np.asarray(fh(*(kw[a] for a in args)))
        assert np.allclose(od, oh, rtol=1e-5, atol=1e-5), name
        locd, loch = _loc(mk_d), _loc(mk_h)
        emit(f"dappa/{name}/pattern_us", td,
             f"LOC={locd} (patterns)")
        emit(f"dappa/{name}/handwritten_us", th,
             f"LOC={loch}; overhead={td / th:.2f}x")
    # distributed lowering cross-check: same pipelines on a data mesh
    # (exercises the shard_map path when >1 device is visible)
    if jax.device_count() > 1:
        mesh = make_mesh((jax.device_count(),), ("data",))
        x, y = dappa.input_stream("x"), dappa.input_stream("y")
        dot = x.zip(y).map(lambda t: t[..., 0] * t[..., 1]).reduce("sum")
        fd = dappa.compile_pipeline(dot, mesh=mesh)
        td = _time(lambda **k: fd(**k), {"x": xs, "y": ys})
        assert np.allclose(np.asarray(fd(x=xs, y=ys)),
                           np.asarray(xs @ ys), rtol=1e-5)
        emit("dappa/dot_product/distributed_us", td,
             f"data mesh over {jax.device_count()} devices")
    emit("dappa/summary", 0,
         "patterns match hand-written results on all workloads "
         "(thesis: 94% LOC reduction on UPMEM; here plumbing is smaller "
         "but specs/collectives are fully hidden)")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
