"""Benchmark harness: one module per thesis table/figure (see DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run [--only <bench> [--only <bench>]]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_damov_classify, bench_dappa_productivity,
                        bench_kernels, bench_mimdram_utilization,
                        bench_proteus_precision, bench_serve, bench_train)

BENCHES = {
    "damov_classify": bench_damov_classify,
    "mimdram_utilization": bench_mimdram_utilization,
    "proteus_precision": bench_proteus_precision,
    "dappa_productivity": bench_dappa_productivity,
    "kernels": bench_kernels,
    "serve": bench_serve,
    "train": bench_train,
}


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    choices=list(BENCHES),
                    help="run only these benches (repeatable)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, mod in BENCHES.items():
        if args.only and name not in args.only:
            continue
        t0 = time.time()
        try:
            mod.run(emit)
            emit(f"{name}/_wall_s", (time.time() - t0) * 1e6, "bench total")
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            emit(f"{name}/_ERROR", 0, f"{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"failed benches: {failed}")


if __name__ == "__main__":
    main()
