"""Training robustness soak (mirrors bench_serve's chaos cells).

Two cells:

* ``train/robust/clean`` — guarded-loop throughput baseline (steps/s with
  the non-finite guard armed and checkpointing on);
* ``train/robust/chaos_soak`` — a supervised run under the full train fault
  plan (NaN grads, slow step, loss spike -> rollback, checkpoint write
  failure, torn checkpoint, preemption -> auto-restart) verified
  byte-identical to an uninterrupted reference run. The asserts are gates:
  a soak that fails to skip/rollback/restart, or that breaks resume
  identity, fails the bench.
"""
import shutil
import tempfile
import time

import numpy as np

from repro.configs import RunConfig
from repro.distributed import TrainChaosConfig
from repro.launch.train import train, verify_resume_identity

ARCH = "pimref-100m"
B, S = 4, 32


def run(emit):
    work = tempfile.mkdtemp(prefix="bench_train_")
    try:
        # -- clean guarded throughput ---------------------------------------
        run_cfg = RunConfig(total_steps=10, learning_rate=1e-3,
                            microbatches=1, checkpoint_every=5)
        t0 = time.time()
        clean = train(ARCH, steps=10, batch=B, seq=S, run=run_cfg,
                      checkpoint_dir=f"{work}/clean", log_every=100)
        wall = time.time() - t0
        assert np.isfinite(clean["final_loss"])
        assert clean["skipped_steps"] == 0
        emit("train/robust/clean", wall * 1e6 / 10,
             f"steps_s={10 / wall:.2f};final_loss={clean['final_loss']:.4f};"
             f"skipped={clean['skipped_steps']}")

        # -- chaos soak + resume-identity gate ------------------------------
        steps = 14
        soak_cfg = RunConfig(total_steps=steps, learning_rate=1e-3,
                             microbatches=1, checkpoint_every=4)
        chaos = TrainChaosConfig(
            seed=11, nan_steps=[3, 9], slow_steps=[2], slow_ms=5.0,
            spike_steps=[6], spike_x=50.0,       # -> rollback to step 4
            ckpt_fail_steps=[14],                # final save dies mid-write
            torn_steps=[12],                     # preemption ckpt is torn ->
            preempt=11)                          # resume falls back to 8
        t0 = time.time()
        res = verify_resume_identity(
            ARCH, steps=steps, work_dir=f"{work}/soak", chaos=chaos,
            max_restarts=2, batch=B, seq=S, run=soak_cfg,
            spike_warmup=4, log_every=100)
        wall = time.time() - t0
        out = res["out"]
        kinds = {e["kind"] for e in out["chaos_events"]}
        assert res["identical"], (
            f"resume identity broken: losses={res['losses_match']} "
            f"params={res['params_match']}")
        assert out["skipped_steps"] >= 2       # both NaN steps skipped
        assert out["rollbacks"] >= 1           # spike rolled back
        assert res["restarts"] >= 1            # preemption restarted
        assert out["ckpt_failures"] >= 1       # injected write failure seen
        assert {"nan", "spike", "preempt", "torn", "ckpt_fail"} <= kinds
        emit("train/robust/chaos_soak", wall * 1e6 / steps,
             f"steps={steps};final_loss={out['final_loss']:.4f};"
             f"skipped={out['skipped_steps']};rollbacks={out['rollbacks']};"
             f"anomalies={out['anomalies']};restarts={res['restarts']};"
             f"ckpt_failures={out['ckpt_failures']};"
             f"chaos_events={len(out['chaos_events'])};"
             f"resume_identity={res['identical']}")
    finally:
        shutil.rmtree(work, ignore_errors=True)
