"""DAMOV bottleneck classification across all dry-run cells.

Mirrors thesis Fig 4.1 / Fig 4.26 / Table C.7: every (arch x shape x mesh)
cell classified by its dominant roofline term, plus the two single-metric
views (roofline position, AI) the thesis shows are insufficient alone.

Reads benchmarks/results/*.json (produced by repro.launch.dryrun).
"""
from __future__ import annotations

import glob
import json
import os
from collections import Counter
from typing import Dict, List


def load_rows(results_dir: str = None) -> List[Dict]:
    results_dir = results_dir or os.path.join(os.path.dirname(__file__),
                                              "results")
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def run(emit) -> None:
    rows = [r for r in load_rows() if r.get("status") == "OK"
            and not r.get("tag")]
    if not rows:
        emit("damov_classify/no_results", 0, "run repro.launch.dryrun first")
        return
    classes = Counter()
    for r in rows:
        d = r["damov"]
        classes[d["bottleneck_class"]] += 1
        cell = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        emit(f"damov_classify/{cell}", d["step_time_s"] * 1e6,
             f"class={d['bottleneck_class'].split()[0]}"
             f";rf={d['roofline_fraction']:.3f}"
             f";AI={d['arithmetic_intensity']:.0f}"
             f";useful={d['useful_ratio']:.2f}")
    for clazz, n in sorted(classes.items()):
        emit(f"damov_classify/count[{clazz.split()[0]}]", 0, f"n={n}")
    # the thesis' headline: single metrics disagree with the full classification
    mem_like = [r for r in rows
                if r["damov"]["arithmetic_intensity"] < 240]  # below ridge
    mism = sum(1 for r in mem_like
               if not r["damov"]["bottleneck_class"].startswith(("MEM", "LAT")))
    emit("damov_classify/ridge_rule_mismatches", 0,
         f"{mism}/{len(mem_like)} low-AI cells NOT memory-class "
         "(single-metric insufficiency, thesis Fig 4.1)")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
