"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results JSONs,
and the perf-trajectory JSONs (BENCH_serve.json / BENCH_kernels.json) from
the bench CSV.

    PYTHONPATH=src python -m benchmarks.report [--results DIR] [--tag TAG]
    PYTHONPATH=src python -m benchmarks.report --serve-csv bench.csv \
        [--bench-json BENCH_serve.json]
    PYTHONPATH=src python -m benchmarks.report --kernels-csv bench.csv \
        [--kernels-json BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = [
    "stablelm-3b", "stablelm-1.6b", "internlm2-1.8b", "deepseek-coder-33b",
    "mixtral-8x7b", "kimi-k2-1t-a32b", "recurrentgemma-2b",
    "seamless-m4t-large-v2", "xlstm-125m", "pixtral-12b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(results_dir: str, tag: str = "") -> List[Dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if (r.get("tag") or "") != tag:
            continue
        rows.append(r)
    key = lambda r: (ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER
                     else 99, SHAPE_ORDER.index(r["shape"]), r["mesh"])
    return sorted(rows, key=key)


def fmt_e(x: float) -> str:
    return f"{x:.2e}"


def dryrun_table(rows: List[Dict], mesh_filter: str) -> str:
    out = ["| arch | shape | status | mb | peak GB | steady GB | fits | "
           "compile s |", "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if mesh_filter not in r["mesh"]:
            continue
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP (sub-quadratic "
                       f"rule) | - | - | - | - | - |")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} | "
            f"{r.get('microbatches', '-')} | {m['peak_GB']} | "
            f"{m.get('steady_GB', '-')} | "
            f"{'Y' if m.get('steady_fits_16GB', m['fits_16GB']) else 'N'} | "
            f"{r.get('seconds_compile', '-')} |")
    return "\n".join(out)


def roofline_table(rows: List[Dict], mesh_filter: str = "data=16xmodel=16"
                   ) -> str:
    out = ["| arch | shape | class | compute_s | memory_s | collective_s | "
           "dominant | MODEL/HLO flops | AI | roofline frac | "
           "what would help |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK" or r["mesh"] != mesh_filter:
            continue
        d = r["damov"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {d['bottleneck_class']} | "
            f"{fmt_e(d['compute_s'])} | {fmt_e(d['memory_s'])} | "
            f"{fmt_e(d['collective_s'])} | **{d['dominant']}** | "
            f"{d['useful_ratio']:.2f} | {d['arithmetic_intensity']:.0f} | "
            f"{d['roofline_fraction']:.3f} | {_help_short(d)} |")
    return "\n".join(out)


def _help_short(d: Dict) -> str:
    from repro.core import damov
    r = damov.Roofline(**{k: v for k, v in d.items()})
    return damov.what_would_help(r).split(":")[0]


def collective_table(rows: List[Dict], mesh_filter: str) -> str:
    out = ["| arch | shape | all-reduce GB | all-gather GB | "
           "reduce-scatter GB | all-to-all GB | permute GB | wire total GB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "OK" or r["mesh"] != mesh_filter:
            continue
        d = r["damov"]
        bk = d.get("by_kind", {})
        g = lambda k: f"{bk.get(k, 0) / 1e9:.1f}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {g('all-reduce')} | "
            f"{g('all-gather')} | {g('reduce-scatter')} | {g('all-to-all')} | "
            f"{g('collective-permute')} | "
            f"{d['coll_wire_bytes'] / 1e9:.1f} |")
    return "\n".join(out)


def summary(rows: List[Dict]) -> str:
    ok = sum(r["status"] == "OK" for r in rows)
    sk = sum(r["status"] == "SKIP" for r in rows)
    fa = sum(r["status"] == "FAIL" for r in rows)
    return f"{ok} OK / {sk} SKIP / {fa} FAIL of {len(rows)} cells"


def parse_serve_csv(csv_path: str) -> Dict[str, Dict[str, float]]:
    """Parse ``serve/...`` rows of the run.py CSV into one dict per metric.

    Rows look like ``serve/decoder/fused_chunk8,12.34,tok_s=123.4;...`` —
    the derived column is ``key=value`` pairs separated by ``;``.
    """
    out: Dict[str, Dict[str, float]] = {
        "tokens_s": {}, "dispatches_per_token": {}, "p95_us": {},
        "speedup": {}, "per_token_p50_us": {}, "kv_bytes_per_token": {},
        "kv_pages_peak": {}, "prefix_hits": {},
        "accepted_len_per_draft": {}, "spec_speedup": {},
        "deadline_miss": {}, "shed_events": {}, "retries": {},
        "error_completions": {},
        "fleet_scale_x": {}, "fleet_cores": {}, "fleet_tokens_s_1": {},
        "fleet_tokens_s_2": {}, "failovers": {}, "replays": {},
        "shard_lost": {}, "heartbeat_misses": {}, "dispatches": {},
    }
    with open(csv_path) as f:
        for line in f:
            if not line.startswith("serve/"):
                continue
            name, us, derived = line.strip().split(",", 2)
            key = name[len("serve/"):]
            if key.startswith("_"):       # harness bookkeeping (_wall_s, ...)
                continue
            try:
                out["per_token_p50_us"][key] = float(us)
            except ValueError:
                continue
            for kv in derived.split(";"):
                if "=" not in kv:
                    continue
                k, v = kv.split("=", 1)
                field = {"tok_s": "tokens_s",
                         "disp_per_tok": "dispatches_per_token",
                         "p95_us": "p95_us", "speedup": "speedup",
                         "kv_b_per_tok": "kv_bytes_per_token",
                         "kv_pages_peak": "kv_pages_peak",
                         "prefix_hits": "prefix_hits",
                         "acc_per_draft": "accepted_len_per_draft",
                         "spec_speedup": "spec_speedup",
                         "deadline_miss": "deadline_miss",
                         "shed_events": "shed_events",
                         "retries": "retries",
                         "error_completions": "error_completions",
                         "scale_x": "fleet_scale_x",
                         "cores": "fleet_cores",
                         "tok_s_1": "fleet_tokens_s_1",
                         "tok_s_2": "fleet_tokens_s_2",
                         "failovers": "failovers",
                         "replays": "replays",
                         "shard_lost": "shard_lost",
                         "heartbeat_misses": "heartbeat_misses",
                         "dispatches": "dispatches"}.get(k)
                if field is None:
                    continue
                try:
                    out[field][key] = float(v)
                except ValueError:
                    pass
    return out


def parse_kernels_csv(csv_path: str) -> Dict[str, Dict[str, object]]:
    """Parse ``kernels/flash/...`` rows into one dict per cell.

    Rows look like ``kernels/flash/gqa,12.3,max_err=1.2e-06;pass=True;...``
    — the derived column is ``key=value`` pairs separated by ``;``. Numeric
    values are floated; ``pass`` becomes a bool.
    """
    out: Dict[str, Dict[str, object]] = {}
    with open(csv_path) as f:
        for line in f:
            if not line.startswith("kernels/flash/"):
                continue
            name, _, derived = line.strip().split(",", 2)
            cell = name[len("kernels/flash/"):]
            if cell.startswith("_"):      # harness bookkeeping
                continue
            row: Dict[str, object] = {}
            for kv in derived.split(";"):
                if "=" not in kv:
                    continue
                k, v = kv.split("=", 1)
                if k == "pass" or k.endswith("_pass"):
                    row[k] = v == "True"
                    continue
                try:
                    row[k] = float(v.rstrip("%"))
                except ValueError:
                    row[k] = v
            if row:
                out[cell] = row
    return out


def parse_train_csv(csv_path: str) -> Dict[str, Dict[str, object]]:
    """Parse ``train/robust/...`` rows into one dict per cell.

    Rows look like ``train/robust/chaos_soak,123.4,skipped=2;rollbacks=1;
    resume_identity=True;...`` — numeric values are floated, the
    ``resume_identity`` gate becomes a bool.
    """
    out: Dict[str, Dict[str, object]] = {}
    with open(csv_path) as f:
        for line in f:
            if not line.startswith("train/"):
                continue
            name, us, derived = line.strip().split(",", 2)
            cell = name[len("train/"):]
            if cell.startswith("_"):      # harness bookkeeping
                continue
            row: Dict[str, object] = {"us_per_step": float(us)}
            for kv in derived.split(";"):
                if "=" not in kv:
                    continue
                k, v = kv.split("=", 1)
                if v in ("True", "False"):
                    row[k] = v == "True"
                    continue
                try:
                    row[k] = float(v)
                except ValueError:
                    row[k] = v
            out[cell] = row
    return out


def write_bench_train(csv_path: str, json_path: str) -> None:
    data = parse_train_csv(csv_path)
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {json_path}: {len(data)} train cells")


def write_bench_kernels(csv_path: str, json_path: str) -> None:
    data = parse_kernels_csv(csv_path)
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {json_path}: {len(data)} kernel cells")


def write_bench_serve(csv_path: str, json_path: str) -> None:
    data = parse_serve_csv(csv_path)
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {json_path}: "
          f"{len(data['tokens_s'])} serve rows")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=os.path.join(
        os.path.dirname(__file__), "results"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--serve-csv", default=None,
                    help="run.py CSV to distill into BENCH_serve.json")
    ap.add_argument("--bench-json", default="BENCH_serve.json")
    ap.add_argument("--kernels-csv", default=None,
                    help="run.py CSV to distill into BENCH_kernels.json")
    ap.add_argument("--kernels-json", default="BENCH_kernels.json")
    ap.add_argument("--train-csv", default=None,
                    help="run.py CSV to distill into BENCH_train.json")
    ap.add_argument("--train-json", default="BENCH_train.json")
    args = ap.parse_args()
    if args.serve_csv or args.kernels_csv or args.train_csv:
        if args.serve_csv:
            write_bench_serve(args.serve_csv, args.bench_json)
        if args.kernels_csv:
            write_bench_kernels(args.kernels_csv, args.kernels_json)
        if args.train_csv:
            write_bench_train(args.train_csv, args.train_json)
        return
    rows = load(args.results, args.tag)
    single = [r for r in rows if not r.get("multi_pod")]
    multi = [r for r in rows if r.get("multi_pod")]
    print("## Dry-run: single-pod (16x16 = 256 chips)\n")
    print(summary(single) + "\n")
    print(dryrun_table(single, "data=16"))
    if multi:
        print("\n## Dry-run: multi-pod (2x16x16 = 512 chips)\n")
        print(summary(multi) + "\n")
        print(dryrun_table(multi, "pod=2"))
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(rows))
    print("\n## Collective breakdown (single-pod, per-device GB/step)\n")
    print(collective_table(rows, "data=16xmodel=16"))


if __name__ == "__main__":
    main()
