"""Serving benchmark: per-token dispatch loop vs fused on-device decode.

The UPMEM benchmarking line (arXiv:2105.03814) shows PIM end-to-end
throughput is dominated by host<->device dispatch + transfer, not kernel
time; the serving analogue is the per-token decode loop (1 jit dispatch + 1
host sync per token). This bench measures, per model family on the CPU smoke
configs:

  * dispatches/token        (loop: 1.0; fused: 1/chunk)
  * tokens/s                (and the fused:loop speedup)
  * p50/p95 per-token latency
  * greedy byte-identity between the two engines (correctness gate)

plus the continuous-batching engine draining a mixed-length queue, and the
speculative-decoding cells (n-gram and layer-skip draft-verify inside the
fused scan: accepted_len/draft, spec_speedup, and greedy-identity gates
against the same layout with speculation off).
Emits into the standard ``benchmarks/run.py`` CSV; ``benchmarks/report.py
--serve-csv`` turns those rows into BENCH_serve.json for cross-PR tracking.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.distributed.chaos import ChaosConfig, ShardChaosConfig
from repro.launch.serve import (make_fleet, serve, serve_fleet, serve_queue,
                                synth_requests)

# decoder LM, recurrent (RG-LRU hybrid), MoE — the three serving families
CONFIGS = (
    ("pimref-100m", "decoder"),
    ("recurrentgemma-2b", "recurrent"),
    ("mixtral-8x7b", "moe"),
)
BATCH, PROMPT, GEN, CHUNK = 2, 16, 32, 8


def run(emit) -> None:
    for arch, label in CONFIGS:
        kw = dict(smoke=True, batch=BATCH, prompt_len=PROMPT, gen=GEN,
                  chunk=CHUNK)
        loop = serve(arch, engine="loop", **kw)
        fused = serve(arch, engine="fused", **kw)
        match = bool(np.array_equal(loop["tokens"], fused["tokens"]))
        speedup = fused["throughput_tok_s"] / loop["throughput_tok_s"]
        emit(f"serve/{label}/per_token_loop",
             loop["per_token_p50_s"] * 1e6,
             f"tok_s={loop['throughput_tok_s']:.1f};"
             f"disp_per_tok={loop['dispatches_per_token']:.3f};"
             f"p95_us={loop['per_token_p95_s'] * 1e6:.0f}")
        emit(f"serve/{label}/fused_chunk{CHUNK}",
             fused["per_token_p50_s"] * 1e6,
             f"tok_s={fused['throughput_tok_s']:.1f};"
             f"disp_per_tok={fused['dispatches_per_token']:.3f};"
             f"p95_us={fused['per_token_p95_s'] * 1e6:.0f};"
             f"speedup={speedup:.2f};greedy_match={match}")
        assert match, f"{arch}: fused tokens diverge from per-token loop"
        assert fused["dispatches"] == -(-GEN // CHUNK), \
            f"{arch}: expected 1 dispatch per decode chunk"
        if label == "decoder":
            # dispatch overhead dominates the tiny decoder: fused must win big
            assert speedup >= 3.0, f"{arch}: fused speedup only {speedup:.2f}x"

    # Proteus-quantized KV cache on the decode hot path: tok/s with the
    # int8 cache (in-kernel dequant on TPU; jnp dequant fallback on CPU,
    # where tok/s is not expected to improve — the roofline rows in
    # bench_kernels carry the bytes/token story) + a greedy-agreement gate
    # between the fused and per-token engines under the same quantization.
    os.environ["REPRO_KV_QUANT"] = "int8"
    try:
        kw = dict(smoke=True, batch=BATCH, prompt_len=PROMPT, gen=GEN,
                  chunk=CHUNK)
        loop_q = serve("pimref-100m", engine="loop", **kw)
        fused_q = serve("pimref-100m", engine="fused", **kw)
    finally:
        os.environ.pop("REPRO_KV_QUANT", None)
    match = bool(np.array_equal(loop_q["tokens"], fused_q["tokens"]))
    emit(f"serve/decoder/fused_kvq8_chunk{CHUNK}",
         fused_q["per_token_p50_s"] * 1e6,
         f"tok_s={fused_q['throughput_tok_s']:.1f};"
         f"disp_per_tok={fused_q['dispatches_per_token']:.3f};"
         f"p95_us={fused_q['per_token_p95_s'] * 1e6:.0f};"
         f"greedy_match={match}")
    assert match, "kv-quant int8: fused tokens diverge from per-token loop"

    # Continuous batching over a mixed-length queue with a shared 8-token
    # system prefix — once with the contiguous per-slot cache (the HBM
    # baseline: KV is committed statically up front), then with the paged
    # block-table cache (plain + int8 pages). The paged gates: every request
    # drains to its full greedy length, prefix pages actually hash-consed
    # across concurrent slots, and peak KV HBM per served token strictly
    # below the baseline. greedy_match reports token agreement with the
    # contiguous engine — informational, not a gate: the contiguous engine
    # left-pads prompts (shifted absolute RoPE positions) while the paged
    # engine right-pads, identical only in exact arithmetic; each layout's
    # byte-identity against per-request references is gated in the tests.
    qkw = dict(smoke=True, slots=4, requests=8, prompt_len=PROMPT, gen=16,
               chunk=4, shared_prefix=8)
    eng = serve_queue("pimref-100m", **qkw)
    s = eng.stats
    recompiles = eng.compile_cache_size()
    per_tok_us = 1e6 / max(s["tokens_per_second"], 1e-9)
    emit("serve/engine/mixed_queue", per_tok_us,
         f"tok_s={s['tokens_per_second']:.1f};"
         f"disp_per_tok={s['dispatches_per_token']:.3f};"
         f"kv_b_per_tok={s['kv_bytes_per_token']:.1f};"
         f"requests={len(eng.completions)};prefills={s['prefills']};"
         f"generate_programs={recompiles}")
    assert len(eng.completions) == 8, "queue not fully drained"
    assert recompiles in (None, 1), \
        f"fused generate recompiled: {recompiles} programs"

    base_toks = {c.uid: c.tokens for c in eng.completions}
    for cell, env in (("paged_ps8", {"REPRO_KV_PAGES": "8"}),
                      ("paged_ps8_kvq8", {"REPRO_KV_PAGES": "8",
                                          "REPRO_KV_QUANT": "int8"})):
        os.environ.update(env)
        try:
            peng = serve_queue("pimref-100m", **qkw)
        finally:
            for k in env:
                os.environ.pop(k, None)
        ps = peng.stats
        ptoks = {c.uid: c.tokens for c in peng.completions}
        match = all(np.array_equal(ptoks[u], base_toks[u]) for u in base_toks)
        emit(f"serve/engine/mixed_queue_{cell}",
             1e6 / max(ps["tokens_per_second"], 1e-9),
             f"tok_s={ps['tokens_per_second']:.1f};"
             f"disp_per_tok={ps['dispatches_per_token']:.3f};"
             f"kv_b_per_tok={ps['kv_bytes_per_token']:.1f};"
             f"kv_pages_peak={ps['kv_pages_peak']};"
             f"prefix_hits={ps['prefix_hits']};"
             f"greedy_match={match}")
        assert len(peng.completions) == 8, f"{cell}: queue not fully drained"
        assert all(len(ptoks[u]) == len(base_toks[u]) for u in base_toks), \
            f"{cell}: completion lengths diverge from contiguous engine"
        assert all(c.finish_reason != "error" for c in peng.completions), \
            f"{cell}: error completions in paged drain"
        assert ps["prefix_hits"] > 0, f"{cell}: shared prefix never reused"
        assert ps["kv_bytes_per_token"] < s["kv_bytes_per_token"], (
            f"{cell}: paged KV HBM/token {ps['kv_bytes_per_token']:.1f} not "
            f"below contiguous baseline {s['kv_bytes_per_token']:.1f}")

    # Speculative decoding inside the fused scan, on a repetitive-suffix
    # queue (each prompt tiled from a 4-token period — the prompt-lookup
    # workload). Every spec cell is gated byte-identical against the SAME
    # layout with speculation off, fully drained, and at most the baseline's
    # dispatches/token (the drafter/verifier live inside the existing chunk
    # dispatch). acc_per_draft — mean committed tokens per draft-verify
    # iteration, 1.0 = nothing accepted — is gated > 1.0 on the draft
    # (layer-skip self-speculation) cells; the n-gram cell reports it
    # informationally: with random smoke weights the model's continuation
    # is non-repetitive, so lookup acceptance sits at chance (~1/vocab) —
    # on trained weights this is the cell that wins. spec_speedup is wall
    # clock vs the spec-off baseline; like kvq8, tok/s is not expected to
    # improve on CPU where the extra (k+1)-row verify FLOPs are not free —
    # the gated claims are identity, dispatch parity, and acceptance.
    skw = dict(smoke=True, slots=4, requests=8, prompt_len=PROMPT, gen=16,
               chunk=4, repeat_period=4)
    spec_cells = (
        ("spec_ngram", "ngram", {}),
        ("spec_draft", "draft", {}),
        ("spec_draft_paged_ps8", "draft", {"REPRO_KV_PAGES": "8"}),
        ("spec_draft_paged_ps8_kvq8", "draft", {"REPRO_KV_PAGES": "8",
                                                "REPRO_KV_QUANT": "int8"}),
    )
    spec_base = {}
    for cell, mode, env in spec_cells:
        os.environ.update(env)
        try:
            ekey = tuple(sorted(env.items()))
            if ekey not in spec_base:
                spec_base[ekey] = serve_queue("pimref-100m", spec="off",
                                              **skw)
            beng = spec_base[ekey]
            seng = serve_queue("pimref-100m", spec=mode, spec_k=3, **skw)
        finally:
            for k in env:
                os.environ.pop(k, None)
        bs, ss = beng.stats, seng.stats
        btoks = {c.uid: c.tokens for c in beng.completions}
        stoks = {c.uid: c.tokens for c in seng.completions}
        match = all(np.array_equal(stoks[u], btoks[u]) for u in btoks)
        acc = ss["spec_accepted_len_per_draft"]
        spec_speedup = ss["tokens_per_second"] / bs["tokens_per_second"]
        emit(f"serve/engine/mixed_queue_{cell}",
             1e6 / max(ss["tokens_per_second"], 1e-9),
             f"tok_s={ss['tokens_per_second']:.1f};"
             f"disp_per_tok={ss['dispatches_per_token']:.3f};"
             f"acc_per_draft={acc:.3f};"
             f"accept_hist={'/'.join(map(str, ss['spec_accept_hist']))};"
             f"spec_speedup={spec_speedup:.2f};"
             f"greedy_match={match}")
        assert match, f"{cell}: speculative tokens diverge from spec-off"
        assert len(seng.completions) == 8, f"{cell}: queue not fully drained"
        assert (ss["dispatches_per_token"]
                <= bs["dispatches_per_token"] + 1e-9), (
            f"{cell}: speculation cost dispatches "
            f"({ss['dispatches_per_token']:.3f} > "
            f"{bs['dispatches_per_token']:.3f})")
        if mode == "draft":
            assert acc > 1.0, (
                f"{cell}: accepted_len/draft {acc:.3f} not above the 1.0 "
                "no-speculation floor")

    # Robustness soak: the paged engine drains the mixed queue under
    # deterministic chaos — one request's logits poisoned mid-stream, one
    # transient chunk failure (retried), one slow chunk, and a page steal.
    # Gates: the drain terminates with exactly one completion per request,
    # the injected failure is retried, and every fault-free survivor is
    # byte-identical to a chaos-free drain. Deliberately NOT gated: zero
    # error completions (the poisoned request MUST error, typed) and
    # compile-cache size (quarantine/steal paths may swap programs).
    ckw = dict(smoke=True, slots=4, requests=8, prompt_len=PROMPT, gen=16,
               chunk=4)
    chaos = ChaosConfig(seed=13, nan_targets={2: 3}, fail_chunks=[1],
                        slow_chunks=[2], slow_ms=5.0, pages=2,
                        steal_after_chunk=3)
    os.environ["REPRO_KV_PAGES"] = "8"
    try:
        ceng = serve_queue("pimref-100m", chaos=chaos, **ckw)
        ref = serve_queue("pimref-100m", **ckw)
    finally:
        os.environ.pop("REPRO_KV_PAGES", None)
    cs = ceng.stats
    rtoks = {c.uid: c.tokens for c in ref.completions}
    poisoned = {e["uid"] for e in ceng.chaos_events if e["kind"] == "nan"}
    survivors = [c for c in ceng.completions
                 if c.finish_reason != "error" and c.uid not in poisoned]
    survivor_match = all(
        np.array_equal(c.tokens, rtoks[c.uid]) for c in survivors)
    emit("serve/engine/chaos_soak",
         1e6 / max(cs["tokens_per_second"], 1e-9),
         f"tok_s={cs['tokens_per_second']:.1f};"
         f"deadline_miss={cs['deadline_miss']};"
         f"shed_events={cs['shed_events']};"
         f"retries={cs['retries']};"
         f"error_completions={cs['error_completions']};"
         f"chaos_events={len(ceng.chaos_events)};"
         f"survivors={len(survivors)};"
         f"survivor_match={survivor_match}")
    assert sorted(c.uid for c in ceng.completions) == list(range(8)), \
        "chaos_soak: requests lost or duplicated under chaos"
    assert cs["retries"] >= 1, "chaos_soak: injected failure never retried"
    assert cs["error_completions"] >= 1, \
        "chaos_soak: poisoned request did not error"
    assert survivor_match, \
        "chaos_soak: fault-free survivors diverge from chaos-free drain"

    # Sharded serving fleet — scaling cell: the SAME mixed-length queue
    # drained by 1 vs 2 ``mp`` worker shards (real spawned processes behind
    # the dispatcher facade; periodic checkpoints disabled so the cell
    # measures serving, not snapshot I/O). Both fleets are warmed with a
    # disjoint uid range first, then counters reset, so compile time is
    # excluded from tok/s. The scaling gate is conditional on the runner:
    # on >= 2 cores (CI) two shards must beat one (scale_x > 1.0); on a
    # single core parallel decode is physically impossible and the gate is
    # only that the fleet facade + IPC costs < 25% (scale_x >= 0.75).
    fl_kw = dict(smoke=True, slots=2, prompt_len=PROMPT, gen=16, chunk=4,
                 seed=0)
    fl_reqs = synth_requests("pimref-100m", smoke=True, requests=8,
                             prompt_len=PROMPT, gen=16, seed=0)
    fl_warm = [dataclasses.replace(r, uid=r.uid + 10_000) for r in fl_reqs]
    cores = os.cpu_count() or 1
    fl_tok_s = {}
    for n in (1, 2):
        fleet = make_fleet("pimref-100m", shards=n, backend="mp",
                           checkpoint_every=1_000_000, **fl_kw)
        try:
            fleet.run(list(fl_warm))
            fleet.reset_stats()
            comps = fleet.run(list(fl_reqs))
            uids = sorted(c.uid for c in comps if c.uid < 10_000)
            assert uids == list(range(8)), \
                f"fleet x{n}: requests lost or duplicated: {uids}"
            assert fleet.stats["error_completions"] == 0, \
                f"fleet x{n}: error completions in a fault-free drain"
            fl_tok_s[n] = fleet.stats["tokens_per_second"]
            if n == 2:
                for row in fleet.per_shard_stats():
                    emit(f"serve/fleet/shard{row['shard']}",
                         1e6 / max(row["tok_s"], 1e-9),
                         f"tok_s={row['tok_s']:.1f};"
                         f"dispatches={row['dispatches']};"
                         f"p95_us={row['p95_ms'] * 1e3:.0f};"
                         f"deadline_miss={row['deadline_miss']};"
                         f"error_completions={row['error_completions']}")
                    assert row["tokens_out"] > 0, (
                        f"fleet shard {row['shard']} served no tokens — "
                        "least-loaded routing never reached it")
        finally:
            fleet.close()
    scale_x = fl_tok_s[2] / max(fl_tok_s[1], 1e-9)
    emit("serve/fleet/scaling", 1e6 / max(fl_tok_s[2], 1e-9),
         f"tok_s={fl_tok_s[2]:.1f};tok_s_1={fl_tok_s[1]:.1f};"
         f"tok_s_2={fl_tok_s[2]:.1f};scale_x={scale_x:.3f};cores={cores}")
    if cores >= 2:
        assert scale_x > 1.0, (
            f"fleet: 2 mp shards on {cores} cores did not beat 1 shard "
            f"(scale_x={scale_x:.3f})")
    else:
        assert scale_x >= 0.75, (
            f"fleet: facade+IPC overhead too high on 1 core "
            f"(scale_x={scale_x:.3f})")

    # Fleet chaos soak: a shard kill fired mid-drain on a 2-shard in-process
    # fleet over the paged cache. Gates: exactly one completion per request
    # fleet-wide, at least one failover actually happened, no request had to
    # be abandoned (shard_lost == 0 — the snapshot covered everything), and
    # every completion is byte-identical to a 1-engine chaos-free drain
    # (checkpoints are taken every fleet step, so failover replay loses no
    # committed chunk).
    os.environ["REPRO_KV_PAGES"] = "8"
    try:
        cfl = serve_fleet("pimref-100m", shards=2, backend="inproc",
                          requests=8,
                          fleet_chaos=ShardChaosConfig.parse("kill=1@2"),
                          **fl_kw)
        fref = serve_queue("pimref-100m", slots=2, requests=8,
                           prompt_len=PROMPT, gen=16, chunk=4, seed=0)
    finally:
        os.environ.pop("REPRO_KV_PAGES", None)
    try:
        fs = cfl.stats
        ref_toks = {c.uid: c.tokens for c in fref.completions}
        cfl_toks = {c.uid: c.tokens for c in cfl.completions}
        fl_match = (sorted(cfl_toks) == sorted(ref_toks) and all(
            np.array_equal(cfl_toks[u], ref_toks[u]) for u in ref_toks))
        emit("serve/fleet/chaos_soak",
             1e6 / max(fs["tokens_per_second"], 1e-9),
             f"tok_s={fs['tokens_per_second']:.1f};"
             f"failovers={fs['failovers']};replays={fs['replays']};"
             f"shard_lost={fs['shard_lost']};"
             f"heartbeat_misses={fs['heartbeat_misses']};"
             f"error_completions={fs['error_completions']};"
             f"chaos_events={len(cfl.chaos_events)};"
             f"survivor_match={fl_match}")
        assert sorted(c.uid for c in cfl.completions) == list(range(8)), \
            "fleet chaos_soak: requests lost or duplicated under shard kill"
        assert fs["failovers"] >= 1, \
            "fleet chaos_soak: the shard kill never triggered a failover"
        assert fs["shard_lost"] == 0, \
            "fleet chaos_soak: snapshot failover abandoned a request"
        assert fl_match, ("fleet chaos_soak: completions diverge from the "
                          "chaos-free single-engine drain")
    finally:
        cfl.close()


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
