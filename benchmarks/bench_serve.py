"""Serving benchmark: per-token dispatch loop vs fused on-device decode.

The UPMEM benchmarking line (arXiv:2105.03814) shows PIM end-to-end
throughput is dominated by host<->device dispatch + transfer, not kernel
time; the serving analogue is the per-token decode loop (1 jit dispatch + 1
host sync per token). This bench measures, per model family on the CPU smoke
configs:

  * dispatches/token        (loop: 1.0; fused: 1/chunk)
  * tokens/s                (and the fused:loop speedup)
  * p50/p95 per-token latency
  * greedy byte-identity between the two engines (correctness gate)

plus the continuous-batching engine draining a mixed-length queue.
Emits into the standard ``benchmarks/run.py`` CSV; ``benchmarks/report.py
--serve-csv`` turns those rows into BENCH_serve.json for cross-PR tracking.
"""
from __future__ import annotations

import os

import numpy as np

from repro.launch.serve import serve, serve_queue

# decoder LM, recurrent (RG-LRU hybrid), MoE — the three serving families
CONFIGS = (
    ("pimref-100m", "decoder"),
    ("recurrentgemma-2b", "recurrent"),
    ("mixtral-8x7b", "moe"),
)
BATCH, PROMPT, GEN, CHUNK = 2, 16, 32, 8


def run(emit) -> None:
    for arch, label in CONFIGS:
        kw = dict(smoke=True, batch=BATCH, prompt_len=PROMPT, gen=GEN,
                  chunk=CHUNK)
        loop = serve(arch, engine="loop", **kw)
        fused = serve(arch, engine="fused", **kw)
        match = bool(np.array_equal(loop["tokens"], fused["tokens"]))
        speedup = fused["throughput_tok_s"] / loop["throughput_tok_s"]
        emit(f"serve/{label}/per_token_loop",
             loop["per_token_p50_s"] * 1e6,
             f"tok_s={loop['throughput_tok_s']:.1f};"
             f"disp_per_tok={loop['dispatches_per_token']:.3f};"
             f"p95_us={loop['per_token_p95_s'] * 1e6:.0f}")
        emit(f"serve/{label}/fused_chunk{CHUNK}",
             fused["per_token_p50_s"] * 1e6,
             f"tok_s={fused['throughput_tok_s']:.1f};"
             f"disp_per_tok={fused['dispatches_per_token']:.3f};"
             f"p95_us={fused['per_token_p95_s'] * 1e6:.0f};"
             f"speedup={speedup:.2f};greedy_match={match}")
        assert match, f"{arch}: fused tokens diverge from per-token loop"
        assert fused["dispatches"] == -(-GEN // CHUNK), \
            f"{arch}: expected 1 dispatch per decode chunk"
        if label == "decoder":
            # dispatch overhead dominates the tiny decoder: fused must win big
            assert speedup >= 3.0, f"{arch}: fused speedup only {speedup:.2f}x"

    # Proteus-quantized KV cache on the decode hot path: tok/s with the
    # int8 cache (in-kernel dequant on TPU; jnp dequant fallback on CPU,
    # where tok/s is not expected to improve — the roofline rows in
    # bench_kernels carry the bytes/token story) + a greedy-agreement gate
    # between the fused and per-token engines under the same quantization.
    os.environ["REPRO_KV_QUANT"] = "int8"
    try:
        kw = dict(smoke=True, batch=BATCH, prompt_len=PROMPT, gen=GEN,
                  chunk=CHUNK)
        loop_q = serve("pimref-100m", engine="loop", **kw)
        fused_q = serve("pimref-100m", engine="fused", **kw)
    finally:
        os.environ.pop("REPRO_KV_QUANT", None)
    match = bool(np.array_equal(loop_q["tokens"], fused_q["tokens"]))
    emit(f"serve/decoder/fused_kvq8_chunk{CHUNK}",
         fused_q["per_token_p50_s"] * 1e6,
         f"tok_s={fused_q['throughput_tok_s']:.1f};"
         f"disp_per_tok={fused_q['dispatches_per_token']:.3f};"
         f"p95_us={fused_q['per_token_p95_s'] * 1e6:.0f};"
         f"greedy_match={match}")
    assert match, "kv-quant int8: fused tokens diverge from per-token loop"

    eng = serve_queue("pimref-100m", smoke=True, slots=4, requests=8,
                      prompt_len=PROMPT, gen=16, chunk=4)
    s = eng.stats
    recompiles = eng.compile_cache_size()
    per_tok_us = 1e6 / max(s["tokens_per_second"], 1e-9)
    emit("serve/engine/mixed_queue", per_tok_us,
         f"tok_s={s['tokens_per_second']:.1f};"
         f"disp_per_tok={s['dispatches_per_token']:.3f};"
         f"requests={len(eng.completions)};prefills={s['prefills']};"
         f"generate_programs={recompiles}")
    assert len(eng.completions) == 8, "queue not fully drained"
    assert recompiles in (None, 1), \
        f"fused generate recompiled: {recompiles} programs"


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
