"""Proteus dynamic-precision benchmark (thesis Fig 6.1 / 6.8 / 6.9 analogue).

  (i)  narrow-value distribution of REAL gradients (trains pimref-tiny a few
       steps, reports per-block required-bits histogram — Fig 6.1),
  (ii) representation Pareto: wire-time and error across {bf16, int8, int4}
       x payload size from the cost model (Fig 6.8/6.9 axes),
  (iii) measured quantize->sum->dequantize round-trip cost and accuracy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig
from repro.core import proteus
from repro.kernels.narrow_value import required_bits
from repro.launch.train import train


def run(emit) -> None:
    # (i) narrow values in real gradients
    out = train("pimref-100m", smoke=True, steps=6, batch=4, seq=64,
                run=RunConfig(total_steps=6, microbatches=1), log_every=100)
    # recompute one grad tree
    import repro.models as models
    from repro.data import make_batch_fn
    from repro.configs import get_config, ShapeConfig
    cfg = get_config("pimref-100m", smoke=True)
    model = models.build_model(cfg)
    shape = ShapeConfig("t", 64, 4, "train")
    batch = {k: jnp.asarray(v) for k, v in make_batch_fn(cfg, shape)(0).items()}
    grads = jax.grad(lambda p: model.loss(p, batch))(out["params"])
    bits_needed = []
    for leaf in jax.tree_util.tree_leaves(grads):
        flat = leaf.reshape(-1).astype(jnp.float32)
        n = (flat.shape[0] // 256) * 256
        if n == 0:
            continue
        # express as int codes at int16 granularity, measure true width
        mx = jnp.abs(flat[:n]).max()
        codes = jnp.round(flat[:n] / jnp.maximum(mx, 1e-20) * 32767
                          ).astype(jnp.int32)
        bits_needed.append(np.asarray(required_bits(codes, 256,
                                                    interpret=True)))
    allb = np.concatenate(bits_needed)
    for pct in (25, 50, 75, 95):
        emit(f"proteus/grad_required_bits_p{pct}", 0,
             f"{np.percentile(allb, pct):.0f} of 16 container bits")
    emit("proteus/grad_blocks_narrower_than_8b", 0,
         f"{100 * float((allb <= 8).mean()):.1f}% (narrow-value headroom)")

    # (ii) cost-model Pareto
    cm = proteus.CostModel()
    for n in (10 ** 4, 10 ** 6, 10 ** 8):
        for rep in proteus.REPRESENTATIONS:
            emit(f"proteus/wire_time/{rep.name}/n{n:.0e}",
                 cm.latency(n, rep) * 1e6, f"rel_err={rep.rel_err:.1e}")
        pick = cm.select(n, err_budget=5e-3)
        emit(f"proteus/selected/n{n:.0e}", 0, f"{pick.name} "
             f"({pick.bits}b, uProgram-select cost model)")

    # (ii-b) data-aware selection: same size/budget, different block stats
    uniform = jnp.ones((1 << 20,), jnp.float32) * 3.0
    spiky = jax.random.normal(jax.random.PRNGKey(7), (1 << 20,)) ** 5
    for name, t in (("uniform_blocks", uniform), ("spiky_blocks", spiky)):
        pick = cm.select_for_tensor(t, err_budget=5e-3)
        emit(f"proteus/selected_data_aware/{name}", 0,
             f"{pick.name} (crest={float(proteus.block_crest(t)):.1f}, "
             f"required_bits={int(proteus.required_bits_float(t))})")

    # (iii) measured quantized-reduction roundtrip (CPU walltime + error)
    g = jax.random.normal(jax.random.PRNGKey(0), (1 << 20,), jnp.float32)
    for bits in (8, 4):
        f = jax.jit(lambda x: proteus.dequantize(proteus.quantize(x, bits=bits,
                                                                  block=256)))
        y = f(g)
        jax.block_until_ready(y)
        t0 = time.perf_counter()
        for _ in range(10):
            y = f(g)
        jax.block_until_ready(y)
        us = (time.perf_counter() - t0) / 10 * 1e6
        err = float(jnp.abs(y - g).max() / jnp.abs(g).max())
        emit(f"proteus/quant_roundtrip_int{bits}", us,
             f"1M elems; max rel err {err:.4f}; wire bytes "
             f"{bits}/32 of fp32")


if __name__ == "__main__":
    run(lambda n, t, d: print(f"{n},{t:.2f},{d}"))
