"""Step builders: jit'd train / prefill / decode steps wired to the planner.

``make_train_step`` returns (step_fn, in_shardings, donate) ready for
``jax.jit``; the Proteus variant swaps the implicit cross-pod gradient
all-reduce for a quantized int8 reduction via a partial-manual shard_map over
the 'pod' axis (data/model stay GSPMD-auto) — hierarchical, narrow-value
aware, per DESIGN.md §2.3.
"""
from __future__ import annotations

import functools
import warnings
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import proteus
from repro.core.mimdram import Plan, plan_sharding, use_plan
from repro.launch import specs as specs_lib
from repro.models import layers
from repro.models import module as mod
from repro.optim import Optimizer


def named(plan: Plan, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(plan.mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------
def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                      budget_bytes: float = 5e9) -> int:
    """Pick grad-accumulation factor so saved activations + logits fit.

    Rough per-device model: saved scan carries (B_loc*S*d*2B*L_saved) plus
    logits round-trip (B_loc*S*V_loc*6B)."""
    from repro.core.mimdram import _axis_size  # noqa: PLC0415

    if shape.mode != "train":
        return 1
    if cfg.microbatches_hint:
        return cfg.microbatches_hint
    dw = _axis_size(plan.mesh, plan.rules.get("act_batch")) or 1
    vw = _axis_size(plan.mesh, plan.rules.get("act_vocab")) or 1
    b_loc = max(shape.global_batch // dw, 1)
    saved = b_loc * shape.seq_len * cfg.d_model * 2 * max(cfg.num_layers, 1)
    logits = b_loc * shape.seq_len * (cfg.vocab_size / vw) * 6
    est = saved + logits
    nm = 1
    while est / nm > budget_bytes and nm < b_loc:
        nm *= 2
    return nm


def _loss_and_grads(model, params, batch, nm: int):
    """value_and_grad with optional lax.scan gradient accumulation.

    Gradients are re-pinned to the parameter shardings (ZeRO-2: the data-axis
    reduce-scatter happens per layer inside the loop, not on a full-size
    unsharded stack afterwards)."""
    specs = model.param_specs()
    if nm <= 1:
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        return loss, mod.constrain_tree(grads, specs)

    def split(x):
        return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

    mb = jax.tree_util.tree_map(split, batch)
    # accumulate in fp32 unless the model trains in pure-bf16 params (1T-scale
    # memory budget; see configs/kimi_k2_1t.py)
    all_bf16 = all(l.dtype == jnp.bfloat16
                   for l in jax.tree_util.tree_leaves(params)
                   if jnp.issubdtype(l.dtype, jnp.floating))
    acc_dt = jnp.bfloat16 if all_bf16 else jnp.float32
    zero = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, acc_dt), params)

    def acc(carry, mbatch):
        lsum, gsum = carry
        l, g = jax.value_and_grad(model.loss)(params, mbatch)
        g = mod.constrain_tree(g, specs)
        gsum = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(a.dtype), gsum, g)
        return (lsum + l, mod.constrain_tree(gsum, specs)), None

    (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zero), mb)
    inv = 1.0 / nm
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss * inv, grads


def make_train_step(model, optimizer: Optimizer, plan: Plan, run: RunConfig,
                    *, guard: bool = False, grad_hook=None):
    """Standard GSPMD train step (paper-faithful baseline distribution).

    ``guard=True`` arms the on-device non-finite guard: the step still
    computes gradients and the candidate update, but a non-finite loss or
    gradient norm selects the *old* params/opt_state for every output leaf.
    The select is ``jnp.where`` on the outputs, so buffer donation is
    preserved and a clean step is bitwise-identical to the unguarded step
    (a select with a true predicate is the identity). Guarded metrics are
    ``{"loss", "grad_norm", "skipped"}``; the driver counts consecutive
    ``skipped`` steps and aborts with ``TrainDivergedError`` — one bad batch
    costs one skipped step, never a poisoned parameter tree.

    ``grad_hook(loss, grads, arm) -> (loss, grads)`` is the chaos harness's
    trace-time injection point (see
    :func:`repro.distributed.chaos.nan_grad_hook`). When set, ``train_step``
    takes a trailing traced ``arm`` operand — the ``logits_hook`` pattern
    from ``make_generate_step`` — so one compiled program serves clean and
    poisoned dispatches, and a disarmed dispatch passes through
    bitwise-unchanged.
    """
    from repro.optim import global_norm  # noqa: PLC0415 (package re-export)

    nm = max(run.microbatches, 1)

    def train_step(params, opt_state, batch, arm=None):
        with use_plan(plan):
            loss, grads = _loss_and_grads(model, params, batch, nm)
            if grad_hook is not None:
                loss, grads = grad_hook(loss, grads, arm)
            if not guard:
                new_params, new_opt = optimizer.update(grads, opt_state,
                                                       params)
                return new_params, new_opt, {"loss": loss}
            gnorm = global_norm(grads)
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params, new_opt = optimizer.update(grads, opt_state, params)

            def sel(new, old):
                return jax.tree_util.tree_map(
                    lambda n, o: jnp.where(ok, n, o), new, old)

            metrics = {"loss": loss, "grad_norm": gnorm,
                       "skipped": jnp.logical_not(ok)}
        return sel(new_params, params), sel(new_opt, opt_state), metrics

    return train_step


def make_train_step_proteus(model, optimizer: Optimizer, plan: Plan,
                            run: RunConfig, pod_axis: str = "pod"):
    """Proteus train step: quantized cross-pod gradient reduction.

    Requires a multi-pod mesh; params are replicated across pods (pure DP on
    the pod axis), batch is pod-split. Inside the shard_map the 'data' and
    'model' axes remain auto (GSPMD), so intra-pod distribution is unchanged;
    only the slow inter-pod hop carries int8 payloads.
    """
    mesh = plan.mesh
    assert mesh is not None and pod_axis in mesh.shape, "needs a pod axis"
    n_pods = mesh.shape[pod_axis]
    # plan whose rules never touch the manual pod axis
    inner_rules = {
        k: (tuple(a for a in v if a != pod_axis) or None) if v else v
        for k, v in plan.rules.items()
    }
    inner_plan = Plan(rules=inner_rules, mesh=mesh, cfg=plan.cfg,
                      shape=plan.shape, notes=plan.notes + ("proteus-inner",))

    nm = max(run.microbatches, 1)

    def per_pod(params, opt_state, batch):
        with use_plan(inner_plan):
            loss, grads = _loss_and_grads(model, params, batch, nm)
        grads = proteus.cross_pod_psum(
            grads, pod_axis, bits=run.proteus_grad_bits,
            block=run.proteus_block, mean=True, n_pods=n_pods)
        loss = jax.lax.pmean(loss, pod_axis)
        with use_plan(inner_plan):
            new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    def pod_spec_tree(tree, leading_pod: bool):
        return jax.tree_util.tree_map(
            lambda _: P(pod_axis) if leading_pod else P(), tree)

    def train_step(params, opt_state, batch):
        fn = shard_map(
            per_pod, mesh=mesh,
            in_specs=(pod_spec_tree(params, False),
                      pod_spec_tree(opt_state, False),
                      pod_spec_tree(batch, True)),
            out_specs=(pod_spec_tree(params, False),
                       pod_spec_tree(opt_state, False), {"loss": P()}),
            check_vma=False,
            axis_names=frozenset({pod_axis}))   # partial-manual: data/model stay auto
        return fn(params, opt_state, batch)

    return train_step


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------
def make_prefill_step(model, plan: Plan, max_len: Optional[int] = None,
                      full_logits: bool = False):
    """Prefill step; with ``max_len`` the returned cache is pre-sized for
    ``max_len`` total positions (no repad before decode). ``full_logits``
    returns logits for every position — the paged engine right-pads prompts
    to its bucket and reads the logits at each true prompt end."""
    def prefill_step(params, batch):
        with use_plan(plan):
            if max_len is None:
                return model.prefill(params, batch)
            return model.prefill(params, batch, max_len=max_len,
                                 full_logits=full_logits)
    return prefill_step


def make_decode_step(model, plan: Plan):
    def decode_step(params, cache, tokens):
        with use_plan(plan):
            return model.decode_step(params, cache, tokens)
    return decode_step


def logits_transform(logits: jax.Array, temperature: float,
                     top_k: int) -> jax.Array:
    """Temperature/top-k logits transform shared by the sampler and the
    speculative verifier's acceptance rule: fp32 scale by ``temperature``,
    then mask everything below the k-th highest logit to -1e30. Requires
    ``temperature > 0`` (greedy selection never calls this)."""
    lf = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    return lf


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float = 0.0,
                  top_k: int = 0) -> jax.Array:
    """On-device token selection. logits: (..., V) -> (...) int32.

    temperature <= 0 means greedy argmax (key unused); top_k > 0 restricts
    sampling to the k highest-probability tokens. Shape-generic over leading
    axes: the fused loop passes (B, V) rows, the speculative verifier passes
    a whole (B, k+1, V) block and gets one target per drafted position.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits_transform(logits, temperature, top_k)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def spec_config(model, mode: Optional[str] = None,
                k: Optional[int] = None) -> Tuple[str, int]:
    """Effective ``(mode, k)`` for speculative decoding on ``model``.

    ``mode``/``k`` default to the ``REPRO_SPEC_DECODE`` / ``REPRO_SPEC_K``
    env knobs. Speculation is gated to the TransformerLM families
    (dense/moe/vlm) with full attention: recurrent/SSM/enc-dec decode paths
    have no multi-token verify step, and a sliding-window ring cache has no
    slack for the k-ahead speculative writes (the window would wrap onto
    rows the verify block still attends to). Unsupported combinations warn
    once and fall back to ``("off", 0)`` — serving keeps working.
    """
    from repro.kernels import common as kcommon

    mode = kcommon.spec_decode_mode() if mode is None else mode
    if mode not in kcommon.SPEC_DECODE_MODES:
        raise ValueError(f"spec mode {mode!r}: expected one of "
                         f"{kcommon.SPEC_DECODE_MODES}")
    if mode == "off":
        return "off", 0
    k = kcommon.spec_draft_len() if k is None else int(k)
    cfg = getattr(model, "cfg", None)
    fam = getattr(cfg, "family", None)
    if fam not in ("dense", "moe", "vlm"):
        warnings.warn(f"spec-decode {mode!r} unsupported for family {fam!r}; "
                      "falling back to off")
        return "off", 0
    if getattr(cfg, "attention_kind", "full") == "sliding":
        warnings.warn(f"spec-decode {mode!r} unsupported with sliding-window "
                      "attention; falling back to off")
        return "off", 0
    return mode, k


def ngram_draft(hist: jax.Array, hist_len: jax.Array, t0: jax.Array,
                k: int) -> jax.Array:
    """Device-side n-gram/prompt-lookup drafter: (B, k) draft tokens.

    ``hist`` (B, Hcap) holds each slot's committed prompt+emitted tokens
    (``hist_len`` valid, zero-padded); ``t0`` (B,) is the token about to be
    emitted. Finds the most recent prior occurrence of ``t0`` — preferring a
    bigram match where the preceding token also equals ``hist[len-1]`` — and
    drafts the k tokens that followed it. Matches are restricted to
    positions with at least one following token; a miss (or a continuation
    running past ``hist_len``) yields garbage drafts, which are SAFE: the
    verifier only commits tokens the target model confirms.
    """
    B, H = hist.shape
    b = jnp.arange(B, dtype=jnp.int32)
    pos = jnp.arange(H, dtype=jnp.int32)
    valid = pos[None, :] < (hist_len - 1)[:, None]        # continuation exists
    t_prev = hist[b, jnp.maximum(hist_len - 1, 0)]        # token before t0
    prev_col = jnp.concatenate(
        [jnp.full((B, 1), -1, hist.dtype), hist[:, :-1]], axis=1)
    uni = (hist == t0[:, None]) & valid
    bi = uni & (prev_col == t_prev[:, None])
    j_bi = jnp.max(jnp.where(bi, pos[None, :], -1), axis=1)
    j_uni = jnp.max(jnp.where(uni, pos[None, :], -1), axis=1)
    j = jnp.where(j_bi >= 0, j_bi, j_uni)                 # -1 on miss
    src = jnp.clip(j[:, None] + 1 + jnp.arange(k, dtype=jnp.int32)[None, :],
                   0, H - 1)
    return hist[b[:, None], src].astype(t0.dtype)


def make_serving_jits(model, plan: Plan, *, max_len: int, chunk: int,
                      temperature: float = 0.0, top_k: int = 0,
                      full_logits: bool = False,
                      spec: Optional[str] = None,
                      spec_k: Optional[int] = None,
                      logits_hook=None):
    """Sharding-pinned (prefill, generate, rep, cache_sh) for one serving cell.

    Cache (and fed-back token/key) shardings are pinned identically on both
    jits so prefill's cache has exactly the signature generate emits — each
    program compiles once; every chunk after the first is a compile-cache
    hit. With a mesh-less plan the pins are skipped (rep/cache_sh = None).

    ``spec``/``spec_k`` (default: the env knobs via :func:`spec_config`)
    select the speculative-decoding drafter. In a spec mode the cache is
    sized for ``max_len + spec_k`` positions — each verify block writes up to
    ``spec_k`` rows past the fed position, and the extra slack guarantees
    those k-ahead writes never wrap onto rows the block still attends to —
    and ``generate`` takes/returns the drafter history (see
    :func:`make_generate_step`), with the history buffers donated alongside
    the cache.

    ``logits_hook`` (see :func:`make_generate_step`) adds a trailing traced
    ``arm`` operand to ``generate`` — the chaos harness's NaN-injection
    point. The hook is trace-time only; arming is per-dispatch data, so one
    compiled program serves both poisoned and clean chunks.
    """
    spec, spec_k = spec_config(model, spec, spec_k)
    if plan.mesh is not None:
        rep = NamedSharding(plan.mesh, P())
        cache_sh = named(plan, specs_lib.cache_pspecs(model, plan))
    else:
        rep = cache_sh = None
    cache_len = max_len + (spec_k if spec != "off" else 0)
    prefill = jax.jit(make_prefill_step(model, plan, max_len=cache_len,
                                        full_logits=full_logits),
                      out_shardings=(None, cache_sh))
    gen_fn = make_generate_step(model, plan, chunk=chunk,
                                temperature=temperature, top_k=top_k,
                                spec=spec, spec_k=spec_k,
                                logits_hook=logits_hook)
    if spec == "off":
        generate = jax.jit(gen_fn, donate_argnums=(1,),
                           out_shardings=(cache_sh,) + (rep,) * 6)
    else:
        generate = jax.jit(gen_fn, donate_argnums=(1, 5, 6),
                           out_shardings=(cache_sh,) + (rep,) * 9)
    return prefill, generate, rep, cache_sh


def make_generate_step(model, plan: Plan, *, chunk: int,
                       temperature: float = 0.0, top_k: int = 0,
                       spec: str = "off", spec_k: int = 0,
                       logits_hook=None):
    """Fused decode loop: ``chunk`` iterations per dispatch via ``lax.scan``.

    The per-token serving loop pays one jit dispatch + one host sync per
    generated token; this rolls the whole decode loop (cache update, forward,
    sampling) into ONE on-device program. Jit it with ``donate_argnums=(1,)``
    so the cache is updated in place (no second live copy).

        generate_step(params, cache, tok, key, eos_id)
            -> (cache, tok, key, done, n_valid, toks, failed)

    ``tok`` (B, 1) is the next token to feed (from prefill argmax or the
    previous chunk); ``toks`` (B, chunk) are the emitted tokens, the first
    being ``tok`` itself — byte-identical to the per-token loop's output.

    EOS detection runs on device: ``eos_id`` is a traced int32 scalar (-1
    disables it; token ids are non-negative, so -1 never matches). The scan
    carries a per-slot ``done`` flag — once a slot emits EOS its sampled
    tokens are frozen (the EOS token is re-fed, so the tail of the chunk is
    deterministic) and ``n_valid`` (B,) counts the tokens up to and including
    EOS. The engine retires slots from ``(done, n_valid)`` without scanning
    token buffers on the host.

    ``failed`` (B,) is the on-device finite guard: True once a slot's logits
    go non-finite. Slots are independent through the whole decode stack, so
    the guard quarantines exactly the poisoned slot — its counting stops with
    the last token sampled from finite logits (already counted in
    ``n_valid``), its re-feed freezes like a done slot, and every other slot
    keeps decoding bit-identically. The engine retires failed slots with an
    error completion instead of poisoning the batch.

    ``logits_hook`` — ``hook(logits, row_pos, arm) -> logits`` with
    ``row_pos`` (B, S) the absolute cache position of each logits row — is
    the chaos harness's deterministic NaN-injection point, applied where a
    real model overflow would appear (before the guard and the sampler).
    When set, the jit takes a trailing traced ``arm`` (B,) int32 operand
    (poison position per slot, -1 disarmed) so one compiled program covers
    armed and clean dispatches.

    Speculative decoding (``spec="ngram"|"draft"``, draft length ``spec_k``)
    keeps the same chunked scan — still ONE dispatch per chunk — but each
    iteration drafts k tokens, runs one (k+1)-token verify block through
    ``decode_step`` (the multi-query shape the decode kernels already take),
    and commits only the leading drafts whose next-token targets confirm
    them, plus the model's own "bonus" token prediction after the last
    accepted draft. Rollback is positional: ``cache["pos"]`` rewinds to the
    committed length and the next iteration's (k+1)-row write window exactly
    covers the rejected rows before anything attends to them, so no KV data
    movement is needed for ring, paged, or quantized layouts. Signature
    grows the drafter history (``hist`` (B, Hcap) committed prompt+output
    tokens, ``hist_len`` (B,)) and the per-iteration accept counts:

        generate_step(params, cache, tok, key, eos_id, hist, hist_len)
            -> (cache, tok, key, done, n_valid, toks, hist, hist_len, acc,
                failed)

    ``toks`` is a compacted (B, chunk*(k+1)) buffer — the first ``n_valid``
    entries per row are the emitted tokens, so callers consume it exactly
    like the non-spec (B, chunk) buffer. ``acc`` (B, chunk) is the number of
    tokens committed by each iteration (1..k+1; -1 for already-done slots) —
    the engine's accepted-length histogram. Greedy (temperature <= 0) output
    is byte-identical to ``spec="off"``; sampled speculation draws the
    (k+1)-position targets from one key split per iteration via the shared
    :func:`sample_tokens`, which is distribution-exact for a deterministic
    drafter but follows a different key schedule than the per-token loop.
    """
    if spec == "off":
        def generate_step(params, cache, tok, key, eos_id, arm=None):
            with use_plan(plan):
                B = tok.shape[0]

                def body(carry, _):
                    cache, tok, key, done, n_valid, failed = carry
                    emitted = tok[:, 0]
                    done_now = done | (emitted == eos_id)
                    n_valid = n_valid + jnp.where(done | failed, 0,
                                                  1).astype(jnp.int32)
                    pos0 = cache["pos"]
                    logits, cache = model.decode_step(params, cache, tok)
                    if logits_hook is not None:
                        logits = logits_hook(logits, pos0[:, None], arm)
                    fin = layers.slot_isfinite(logits)
                    failed_now = failed | (~fin & ~done_now)
                    key, sub = jax.random.split(key)
                    nxt = sample_tokens(logits[:, -1], sub, temperature, top_k)
                    nxt = jnp.where(done_now | failed_now, emitted, nxt)
                    return (cache, nxt[:, None], key, done_now, n_valid,
                            failed_now), emitted

                done0 = jnp.zeros((B,), bool)
                n0 = jnp.zeros((B,), jnp.int32)
                f0 = jnp.zeros((B,), bool)
                (cache, tok, key, done, n_valid, failed), toks = jax.lax.scan(
                    body, (cache, tok, key, done0, n0, f0), None, length=chunk)
            return (cache, tok, key, done, n_valid, toks.T,
                    failed)                                 # toks: (B, chunk)
        return generate_step

    k = int(spec_k)
    span = k + 1
    if spec == "draft":
        from repro.kernels import common as kcommon
        n_draft_layers = (kcommon.spec_draft_layers()
                          or max(1, model.cfg.num_layers // 2))
        n_draft_layers = min(n_draft_layers, model.cfg.num_layers)

    def generate_step(params, cache, tok, key, eos_id, hist, hist_len,
                      arm=None):
        with use_plan(plan):
            B = tok.shape[0]
            Hcap = hist.shape[1]
            Lbuf = chunk * span
            b = jnp.arange(B, dtype=jnp.int32)
            idx = jnp.arange(span, dtype=jnp.int32)

            def body(carry, _):
                (cache, tok, key, done, n_valid, failed, hist, hist_len,
                 toks) = carry
                t0 = tok[:, 0]
                if spec == "ngram":
                    drafts = ngram_draft(hist, hist_len, t0, k)
                else:
                    # layer-skip self-drafting: k greedy single-token steps
                    # through the first n_draft_layers of the target itself,
                    # scribbling scratch KV the verify block overwrites.
                    pos_in = cache["pos"]

                    def dbody(dcarry, _):
                        dc, dt = dcarry
                        dlogits, dc = model.decode_step(
                            params, dc, dt, layers=n_draft_layers)
                        nt = jnp.argmax(dlogits[:, -1], axis=-1)
                        nt = nt.astype(t0.dtype)
                        return (dc, nt[:, None]), nt

                    (cache, _), dr = jax.lax.scan(
                        dbody, (cache, tok), None, length=k)
                    cache = dict(cache, pos=pos_in)
                    drafts = dr.T
                blk = jnp.concatenate([tok, drafts], axis=1)   # (B, k+1)
                pos0 = cache["pos"]
                logits, cache = model.decode_step(params, cache, blk)
                if logits_hook is not None:
                    row_pos = pos0[:, None] + idx[None, :]
                    logits = logits_hook(logits, row_pos, arm)
                # per-row finite guard: row j's logits are the target for
                # draft j+1 and the bonus after j accepts. Acceptance stops
                # at the last finite target, so every committed token derives
                # from finite logits; the iteration whose bonus row is the
                # non-finite one quarantines the slot — exactly matching the
                # non-spec loop's "last token counted, next token lost".
                fin_row = jnp.isfinite(logits).all(axis=-1)    # (B, span)
                key, sub = jax.random.split(key)
                tgt = sample_tokens(logits, sub, temperature, top_k)
                ok = (blk[:, 1:] == tgt[:, :-1]).astype(jnp.int32)
                ok = ok * fin_row[:, :-1].astype(jnp.int32)
                a = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)   # 0..k accepted
                # commit blk[:, :a+1], truncated at the first EOS (inclusive)
                is_eos = blk == eos_id
                eos_hit = is_eos & (idx[None, :] <= a[:, None])
                any_eos = eos_hit.any(axis=1)
                first_eos = jnp.min(
                    jnp.where(eos_hit, idx[None, :], span), axis=1)
                cnt = jnp.where(any_eos, first_eos + 1, a + 1)
                cnt = jnp.where(done | failed, 0, cnt).astype(jnp.int32)
                bonus_fin = jnp.take_along_axis(
                    fin_row, a[:, None], axis=1)[:, 0]
                failed_now = failed | (~bonus_fin & ~any_eos & ~done)
                # rollback = positional rewind: the next iteration's k+1-row
                # write window starts at pos0+cnt, covering every rejected row
                # before anything attends to it (done slots advance 1, like
                # the non-spec loop's frozen re-feed).
                adv = jnp.maximum(cnt, 1).astype(pos0.dtype)
                cache = dict(cache, pos=pos0 + adv)
                bonus = tgt[b, a]
                nxt = jnp.where(any_eos, jnp.asarray(eos_id, t0.dtype), bonus)
                nxt = jnp.where(done | failed_now, t0, nxt)    # freeze re-feed
                wv = idx[None, :] < cnt[:, None]
                tslot = jnp.where(wv, n_valid[:, None] + idx[None, :], Lbuf)
                toks = toks.at[b[:, None], tslot].set(blk, mode="drop")
                hslot = jnp.where(wv, hist_len[:, None] + idx[None, :], Hcap)
                hist = hist.at[b[:, None], hslot].set(
                    blk.astype(hist.dtype), mode="drop")
                acc_i = jnp.where(done | failed, -1, cnt)
                return (cache, nxt[:, None], key, done | any_eos,
                        n_valid + cnt, failed_now, hist, hist_len + cnt,
                        toks), acc_i

            done0 = jnp.zeros((B,), bool)
            n0 = jnp.zeros((B,), jnp.int32)
            f0 = jnp.zeros((B,), bool)
            toks0 = jnp.zeros((B, Lbuf), tok.dtype)
            carry0 = (cache, tok, key, done0, n0, f0, hist, hist_len, toks0)
            (cache, tok, key, done, n_valid, failed, hist, hist_len,
             toks), acc = jax.lax.scan(body, carry0, None, length=chunk)
        return (cache, tok, key, done, n_valid, toks, hist, hist_len,
                acc.T, failed)                                 # acc: (B, chunk)
    return generate_step


# ---------------------------------------------------------------------------
# Assembly for one cell: abstract inputs + shardings (dry-run / launcher)
# ---------------------------------------------------------------------------
def cell_artifacts(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                   run: RunConfig, optimizer_name: Optional[str] = None):
    """Returns (model, step_fn, abstract_args, in_shardings, donate, run)."""
    from repro.models import build_model
    from repro.optim import make_optimizer

    model = build_model(cfg)
    pspecs = mod.param_pspecs(model.param_specs(), plan)
    abstract_p = mod.abstract_params(model.param_specs())
    batch = specs_lib.input_specs(cfg, shape)
    batch_ps = specs_lib.batch_pspecs(cfg, shape, plan)

    if shape.mode == "train":
        if run.microbatches == 0:
            run = run.replace(microbatches=auto_microbatches(cfg, shape, plan))
        opt = make_optimizer(optimizer_name or cfg.optimizer, run)
        ostate_specs = opt.state_specs(model.param_specs())
        abstract_o = mod.abstract_params(ostate_specs)
        opt_ps = mod.param_pspecs(ostate_specs, plan)
        if run.proteus_enabled and plan.mesh is not None and \
                "pod" in plan.mesh.shape:
            step = make_train_step_proteus(model, opt, plan, run)
        else:
            step = make_train_step(model, opt, plan, run)
        args = (abstract_p, abstract_o, batch)
        shardings = (named(plan, pspecs), named(plan, opt_ps),
                     named(plan, batch_ps))
        return model, step, args, shardings, (0, 1), run, None

    if shape.mode == "prefill":
        step = make_prefill_step(model, plan)
        args = (abstract_p, batch)
        shardings = (named(plan, pspecs), named(plan, batch_ps))
        # pin the returned cache to the serving cache layout (otherwise the
        # scan ys inherit activation sharding and the cache lands 16x fatter)
        cache_out = named(plan, specs_lib.cache_pspecs(model, plan, shape))
        out_sh = (None, cache_out)
        return model, step, args, shardings, (), run, out_sh

    # decode
    step = make_decode_step(model, plan)
    cache = specs_lib.cache_specs(model, shape)
    cache_ps = specs_lib.cache_pspecs(model, plan, shape)
    args = (abstract_p, cache, batch["tokens"])
    shardings = (named(plan, pspecs), named(plan, cache_ps),
                 NamedSharding(plan.mesh, batch_ps["tokens"])
                 if plan.mesh is not None else None)
    out_sh = (None, named(plan, cache_ps))
    return model, step, args, shardings, (1,), run, out_sh
