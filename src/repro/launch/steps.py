"""Step builders: jit'd train / prefill / decode steps wired to the planner.

``make_train_step`` returns (step_fn, in_shardings, donate) ready for
``jax.jit``; the Proteus variant swaps the implicit cross-pod gradient
all-reduce for a quantized int8 reduction via a partial-manual shard_map over
the 'pod' axis (data/model stay GSPMD-auto) — hierarchical, narrow-value
aware, per DESIGN.md §2.3.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core import proteus
from repro.core.mimdram import Plan, plan_sharding, use_plan
from repro.launch import specs as specs_lib
from repro.models import module as mod
from repro.optim import Optimizer


def named(plan: Plan, pspec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(plan.mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------
def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                      budget_bytes: float = 5e9) -> int:
    """Pick grad-accumulation factor so saved activations + logits fit.

    Rough per-device model: saved scan carries (B_loc*S*d*2B*L_saved) plus
    logits round-trip (B_loc*S*V_loc*6B)."""
    from repro.core.mimdram import _axis_size  # noqa: PLC0415

    if shape.mode != "train":
        return 1
    if cfg.microbatches_hint:
        return cfg.microbatches_hint
    dw = _axis_size(plan.mesh, plan.rules.get("act_batch")) or 1
    vw = _axis_size(plan.mesh, plan.rules.get("act_vocab")) or 1
    b_loc = max(shape.global_batch // dw, 1)
    saved = b_loc * shape.seq_len * cfg.d_model * 2 * max(cfg.num_layers, 1)
    logits = b_loc * shape.seq_len * (cfg.vocab_size / vw) * 6
    est = saved + logits
    nm = 1
    while est / nm > budget_bytes and nm < b_loc:
        nm *= 2
    return nm


def _loss_and_grads(model, params, batch, nm: int):
    """value_and_grad with optional lax.scan gradient accumulation.

    Gradients are re-pinned to the parameter shardings (ZeRO-2: the data-axis
    reduce-scatter happens per layer inside the loop, not on a full-size
    unsharded stack afterwards)."""
    specs = model.param_specs()
    if nm <= 1:
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        return loss, mod.constrain_tree(grads, specs)

    def split(x):
        return x.reshape((nm, x.shape[0] // nm) + x.shape[1:])

    mb = jax.tree_util.tree_map(split, batch)
    # accumulate in fp32 unless the model trains in pure-bf16 params (1T-scale
    # memory budget; see configs/kimi_k2_1t.py)
    all_bf16 = all(l.dtype == jnp.bfloat16
                   for l in jax.tree_util.tree_leaves(params)
                   if jnp.issubdtype(l.dtype, jnp.floating))
    acc_dt = jnp.bfloat16 if all_bf16 else jnp.float32
    zero = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, acc_dt), params)

    def acc(carry, mbatch):
        lsum, gsum = carry
        l, g = jax.value_and_grad(model.loss)(params, mbatch)
        g = mod.constrain_tree(g, specs)
        gsum = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(a.dtype), gsum, g)
        return (lsum + l, mod.constrain_tree(gsum, specs)), None

    (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zero), mb)
    inv = 1.0 / nm
    grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
    return loss * inv, grads


def make_train_step(model, optimizer: Optimizer, plan: Plan, run: RunConfig):
    """Standard GSPMD train step (paper-faithful baseline distribution)."""
    nm = max(run.microbatches, 1)

    def train_step(params, opt_state, batch):
        with use_plan(plan):
            loss, grads = _loss_and_grads(model, params, batch, nm)
            new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    return train_step


def make_train_step_proteus(model, optimizer: Optimizer, plan: Plan,
                            run: RunConfig, pod_axis: str = "pod"):
    """Proteus train step: quantized cross-pod gradient reduction.

    Requires a multi-pod mesh; params are replicated across pods (pure DP on
    the pod axis), batch is pod-split. Inside the shard_map the 'data' and
    'model' axes remain auto (GSPMD), so intra-pod distribution is unchanged;
    only the slow inter-pod hop carries int8 payloads.
    """
    mesh = plan.mesh
    assert mesh is not None and pod_axis in mesh.shape, "needs a pod axis"
    n_pods = mesh.shape[pod_axis]
    # plan whose rules never touch the manual pod axis
    inner_rules = {
        k: (tuple(a for a in v if a != pod_axis) or None) if v else v
        for k, v in plan.rules.items()
    }
    inner_plan = Plan(rules=inner_rules, mesh=mesh, cfg=plan.cfg,
                      shape=plan.shape, notes=plan.notes + ("proteus-inner",))

    nm = max(run.microbatches, 1)

    def per_pod(params, opt_state, batch):
        with use_plan(inner_plan):
            loss, grads = _loss_and_grads(model, params, batch, nm)
        grads = proteus.cross_pod_psum(
            grads, pod_axis, bits=run.proteus_grad_bits,
            block=run.proteus_block, mean=True, n_pods=n_pods)
        loss = jax.lax.pmean(loss, pod_axis)
        with use_plan(inner_plan):
            new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss}

    def pod_spec_tree(tree, leading_pod: bool):
        return jax.tree_util.tree_map(
            lambda _: P(pod_axis) if leading_pod else P(), tree)

    def train_step(params, opt_state, batch):
        fn = shard_map(
            per_pod, mesh=mesh,
            in_specs=(pod_spec_tree(params, False),
                      pod_spec_tree(opt_state, False),
                      pod_spec_tree(batch, True)),
            out_specs=(pod_spec_tree(params, False),
                       pod_spec_tree(opt_state, False), {"loss": P()}),
            check_vma=False,
            axis_names=frozenset({pod_axis}))   # partial-manual: data/model stay auto
        return fn(params, opt_state, batch)

    return train_step


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------
def make_prefill_step(model, plan: Plan, max_len: Optional[int] = None,
                      full_logits: bool = False):
    """Prefill step; with ``max_len`` the returned cache is pre-sized for
    ``max_len`` total positions (no repad before decode). ``full_logits``
    returns logits for every position — the paged engine right-pads prompts
    to its bucket and reads the logits at each true prompt end."""
    def prefill_step(params, batch):
        with use_plan(plan):
            if max_len is None:
                return model.prefill(params, batch)
            return model.prefill(params, batch, max_len=max_len,
                                 full_logits=full_logits)
    return prefill_step


def make_decode_step(model, plan: Plan):
    def decode_step(params, cache, tokens):
        with use_plan(plan):
            return model.decode_step(params, cache, tokens)
    return decode_step


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: float = 0.0,
                  top_k: int = 0) -> jax.Array:
    """On-device next-token selection. logits: (B, V) -> (B,) int32.

    temperature <= 0 means greedy argmax (key unused); top_k > 0 restricts
    sampling to the k highest-probability tokens.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < kth, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)


def make_serving_jits(model, plan: Plan, *, max_len: int, chunk: int,
                      temperature: float = 0.0, top_k: int = 0,
                      full_logits: bool = False):
    """Sharding-pinned (prefill, generate, rep, cache_sh) for one serving cell.

    Cache (and fed-back token/key) shardings are pinned identically on both
    jits so prefill's cache has exactly the signature generate emits — each
    program compiles once; every chunk after the first is a compile-cache
    hit. With a mesh-less plan the pins are skipped (rep/cache_sh = None).
    """
    if plan.mesh is not None:
        rep = NamedSharding(plan.mesh, P())
        cache_sh = named(plan, specs_lib.cache_pspecs(model, plan))
    else:
        rep = cache_sh = None
    prefill = jax.jit(make_prefill_step(model, plan, max_len=max_len,
                                        full_logits=full_logits),
                      out_shardings=(None, cache_sh))
    generate = jax.jit(
        make_generate_step(model, plan, chunk=chunk, temperature=temperature,
                           top_k=top_k),
        donate_argnums=(1,),
        out_shardings=(cache_sh, rep, rep, rep, rep, rep))
    return prefill, generate, rep, cache_sh


def make_generate_step(model, plan: Plan, *, chunk: int,
                       temperature: float = 0.0, top_k: int = 0):
    """Fused decode loop: ``chunk`` tokens per dispatch via ``jax.lax.scan``.

    The per-token serving loop pays one jit dispatch + one host sync per
    generated token; this rolls the whole decode loop (cache update, forward,
    sampling) into ONE on-device program. Jit it with ``donate_argnums=(1,)``
    so the cache is updated in place (no second live copy).

        generate_step(params, cache, tok, key, eos_id)
            -> (cache, tok, key, done, n_valid, toks)

    ``tok`` (B, 1) is the next token to feed (from prefill argmax or the
    previous chunk); ``toks`` (B, chunk) are the emitted tokens, the first
    being ``tok`` itself — byte-identical to the per-token loop's output.

    EOS detection runs on device: ``eos_id`` is a traced int32 scalar (-1
    disables it; token ids are non-negative, so -1 never matches). The scan
    carries a per-slot ``done`` flag — once a slot emits EOS its sampled
    tokens are frozen (the EOS token is re-fed, so the tail of the chunk is
    deterministic) and ``n_valid`` (B,) counts the tokens up to and including
    EOS. The engine retires slots from ``(done, n_valid)`` without scanning
    token buffers on the host.
    """

    def generate_step(params, cache, tok, key, eos_id):
        with use_plan(plan):
            B = tok.shape[0]

            def body(carry, _):
                cache, tok, key, done, n_valid = carry
                emitted = tok[:, 0]
                done_now = done | (emitted == eos_id)
                n_valid = n_valid + jnp.where(done, 0, 1).astype(jnp.int32)
                logits, cache = model.decode_step(params, cache, tok)
                key, sub = jax.random.split(key)
                nxt = sample_tokens(logits[:, -1], sub, temperature, top_k)
                nxt = jnp.where(done_now, emitted, nxt)   # freeze after EOS
                return (cache, nxt[:, None], key, done_now, n_valid), emitted

            done0 = jnp.zeros((B,), bool)
            n0 = jnp.zeros((B,), jnp.int32)
            (cache, tok, key, done, n_valid), toks = jax.lax.scan(
                body, (cache, tok, key, done0, n0), None, length=chunk)
        return cache, tok, key, done, n_valid, toks.T    # toks: (B, chunk)
    return generate_step


# ---------------------------------------------------------------------------
# Assembly for one cell: abstract inputs + shardings (dry-run / launcher)
# ---------------------------------------------------------------------------
def cell_artifacts(cfg: ModelConfig, shape: ShapeConfig, plan: Plan,
                   run: RunConfig, optimizer_name: Optional[str] = None):
    """Returns (model, step_fn, abstract_args, in_shardings, donate, run)."""
    from repro.models import build_model
    from repro.optim import make_optimizer

    model = build_model(cfg)
    pspecs = mod.param_pspecs(model.param_specs(), plan)
    abstract_p = mod.abstract_params(model.param_specs())
    batch = specs_lib.input_specs(cfg, shape)
    batch_ps = specs_lib.batch_pspecs(cfg, shape, plan)

    if shape.mode == "train":
        if run.microbatches == 0:
            run = run.replace(microbatches=auto_microbatches(cfg, shape, plan))
        opt = make_optimizer(optimizer_name or cfg.optimizer, run)
        ostate_specs = opt.state_specs(model.param_specs())
        abstract_o = mod.abstract_params(ostate_specs)
        opt_ps = mod.param_pspecs(ostate_specs, plan)
        if run.proteus_enabled and plan.mesh is not None and \
                "pod" in plan.mesh.shape:
            step = make_train_step_proteus(model, opt, plan, run)
        else:
            step = make_train_step(model, opt, plan, run)
        args = (abstract_p, abstract_o, batch)
        shardings = (named(plan, pspecs), named(plan, opt_ps),
                     named(plan, batch_ps))
        return model, step, args, shardings, (0, 1), run, None

    if shape.mode == "prefill":
        step = make_prefill_step(model, plan)
        args = (abstract_p, batch)
        shardings = (named(plan, pspecs), named(plan, batch_ps))
        # pin the returned cache to the serving cache layout (otherwise the
        # scan ys inherit activation sharding and the cache lands 16x fatter)
        cache_out = named(plan, specs_lib.cache_pspecs(model, plan, shape))
        out_sh = (None, cache_out)
        return model, step, args, shardings, (), run, out_sh

    # decode
    step = make_decode_step(model, plan)
    cache = specs_lib.cache_specs(model, shape)
    cache_ps = specs_lib.cache_pspecs(model, plan, shape)
    args = (abstract_p, cache, batch["tokens"])
    shardings = (named(plan, pspecs), named(plan, cache_ps),
                 NamedSharding(plan.mesh, batch_ps["tokens"])
                 if plan.mesh is not None else None)
    out_sh = (None, named(plan, cache_ps))
    return model, step, args, shardings, (1,), run, out_sh
