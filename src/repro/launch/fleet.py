"""ServeFleet: N engine shards behind one submit/step/run facade.

The HBM-PIMulator architecture — one controller per memory channel behind a
single ``send/tick`` memory-system facade, per-channel stats registered
centrally — mapped onto serving: each shard is a full
:class:`~repro.launch.engine.ServeEngine` (its own params, cache, page pool
and jits), and the fleet is the facade that routes, health-checks, and
fails over. UPMEM deployments drive ~2,500 independent DPU ranks this way,
and any rank can stall or die independently (arXiv:2105.03814) — so the
shard, not the request, is the failure domain here.

Two backends, one protocol:

* ``inproc`` — shards are plain objects in this process. Deterministic and
  fast; what the tests and chaos drills use.
* ``mp``     — each shard is a ``multiprocessing`` (spawn) worker owning
  its engine, driven over a Pipe. The fleet sends every routable shard its
  step command *first*, then collects replies, so shard chunks overlap
  across processes — the CPU stand-in for a multi-host deployment.

Every shard step doubles as a heartbeat: a reply with its ``beat`` flag set
feeds :class:`~repro.distributed.fault_tolerance.HealthMonitor.beat`, a
timeout / dropped flag feeds ``miss`` (escalating LIVE -> SUSPECT -> DEAD),
and an unambiguous death (process exit, closed pipe, raised
:class:`~repro.distributed.chaos.ShardKilledError`) skips straight to
``mark_dead``. On death the fleet **fails over**: the shard's last periodic
``snapshot()`` (optionally persisted as an atomic
:class:`~repro.distributed.fault_tolerance.RestartManifest` per shard) is
replayed into survivor shards — completed-but-undrained requests deliver
directly, in-flight requests resume from their produced tokens where the
paged layout allows (regenerate otherwise; greedy output is byte-identical
either way), and requests routed after the last checkpoint replay from the
retained original Request. Only when no survivor exists or a request
exhausts its replay budget does it complete with the typed
``ErrorReason.SHARD_LOST`` — the fleet-wide invariant is **exactly one
Completion per submitted request**, faults or not.

This module is control plane only: no direct ``jax`` import (enforced by
``tools/check_jax_compat.py``) — all device work lives behind the engine.
"""
from __future__ import annotations

import multiprocessing
import os
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.distributed.chaos import (ShardChaosConfig, ShardChaosMonkey,
                                     ShardKilledError)
from repro.distributed.dispatcher import Dispatcher
from repro.distributed.fault_tolerance import (HealthMonitor, RestartManifest,
                                               ShardState)
from repro.launch.engine import Completion, ErrorReason, Request

# engine stats counters a warm benchmark measurement resets to zero
_RESET_STATS = ("tokens_out", "decode_dispatches", "prefills",
                "error_completions", "deadline_miss")


def _load_entries(eng, snap: Dict[str, Any]) -> None:
    """Replay a (partial) snapshot into a survivor engine, choosing the
    resume mode per entry: paged resume re-prefills prompt + produced and
    needs the grown prompt to fit the engine's bucket, so entries that
    overflow fall back to regenerate-from-scratch (greedy completions are
    byte-identical either way)."""
    comps = list(snap.get("completions") or ())
    if comps:
        eng.load_snapshot({"completions": comps})
    budget = getattr(eng, "_tok_len", None)
    for d in list(snap.get("queued") or ()) + list(snap.get("active") or ()):
        resume = None
        if budget is not None and \
                len(d["tokens"]) + len(d.get("produced") or ()) > budget:
            resume = False
        eng.load_snapshot({"queued": [d]}, resume=resume)


def _reset_engine_stats(eng) -> None:
    for k in _RESET_STATS:
        eng.stats[k] = 0
    eng.stats["wall_seconds"] = 0.0
    eng.stats["chunk_seconds"] = []


def _step_report(eng, drained: int, beat: bool,
                 more: bool) -> Dict[str, Any]:
    """The per-step shard reply: heartbeat flag, progress flag, completions
    emitted since the last report, and the KV-reservation routing signal."""
    new = eng.completions[drained:]
    return {"beat": beat, "more": more, "completions": list(new),
            "reserved": eng.stats.get("kv_pages_reserved", 0)}


class InProcessShard:
    """A shard living in the fleet's own process (tests, chaos drills)."""

    backend = "inproc"

    def __init__(self, sid: int, engine):
        self.sid = sid
        self.eng = engine
        self.pending = False          # inproc replies are always immediate
        self._drained = 0
        self._killed = False
        self._stall_until = -1
        self._drop_until = -1
        self._report: Optional[Dict[str, Any]] = None

    def submit(self, req: Request) -> None:
        self.eng.submit(req)

    def load(self, snap: Dict[str, Any]) -> None:
        _load_entries(self.eng, snap)

    def snapshot(self) -> Dict[str, Any]:
        return self.eng.snapshot()

    def final_stats(self) -> Dict[str, Any]:
        return dict(self.eng.stats)

    def reset_stats(self) -> None:
        _reset_engine_stats(self.eng)

    def kill(self) -> None:
        self._killed = True

    def step_send(self, directive: Optional[Dict[str, Any]],
                  step: int) -> None:
        if self._killed:
            raise ShardKilledError(f"shard {self.sid}: killed by chaos")
        if directive is not None:
            if directive["kind"] == "stall":
                self._stall_until = step + directive["steps"]
            elif directive["kind"] == "drop":
                self._drop_until = step + directive["beats"]
        if step < self._stall_until:     # hung: no work, no heartbeat
            self._report = {"beat": False, "more": True, "completions": [],
                            "reserved": 0}
            return
        more = self.eng.step()
        self._report = _step_report(self.eng, self._drained,
                                    step >= self._drop_until, more)
        self._drained = len(self.eng.completions)

    def step_recv(self, timeout_s: float) -> Optional[Dict[str, Any]]:
        r, self._report = self._report, None
        return r

    def close(self) -> None:
        pass


def _worker_main(conn, spec: Dict[str, Any]) -> None:
    """Entry point of one ``mp`` shard worker (module-level so the spawn
    context can import it). Builds its engine from ``spec`` after replaying
    the recorded env knobs — every shard traces the same programs from the
    same seed, so any shard decodes any request byte-identically."""
    for k, v in spec.get("env", {}).items():
        os.environ[k] = v
    try:
        from repro.launch.serve import make_queue_engine
        eng = make_queue_engine(**spec["engine"])
    except Exception as e:  # noqa: BLE001 — surfaced via the pipe
        conn.send(("error", f"engine build failed: {e!r}"))
        return
    conn.send(("ready", None))
    drained = 0
    drop_until = -1
    while True:
        try:
            cmd, payload = conn.recv()
        except (EOFError, KeyboardInterrupt):
            return
        try:
            if cmd == "stop":
                conn.send(("ok", None))
                return
            if cmd == "submit":
                eng.submit(payload)
                conn.send(("ok", None))
            elif cmd == "load":
                _load_entries(eng, payload)
                conn.send(("ok", None))
            elif cmd == "snapshot":
                conn.send(("snap", eng.snapshot()))
            elif cmd == "stats":
                conn.send(("stats", dict(eng.stats)))
            elif cmd == "reset":
                _reset_engine_stats(eng)
                conn.send(("ok", None))
            elif cmd == "step":
                d, step = payload["directive"], payload["step"]
                if d is not None and d["kind"] == "drop":
                    drop_until = step + d["beats"]
                more = eng.step()
                conn.send(("report", _step_report(eng, drained,
                                                  step >= drop_until, more)))
                drained = len(eng.completions)
            else:
                conn.send(("error", f"unknown command {cmd!r}"))
        except Exception as e:  # noqa: BLE001 — a poisoned engine kills the
            try:                # shard; the fleet fails its work over
                conn.send(("error", repr(e)))
            except Exception:
                pass
            return


class WorkerShard:
    """A shard running as a ``multiprocessing`` (spawn) worker.

    Chaos ``kill`` is a real ``Process.terminate()`` here — detection goes
    through the same observable the production path would use (process
    liveness / closed pipe), not a cooperative flag.
    """

    backend = "mp"

    def __init__(self, sid: int, spec: Dict[str, Any], ctx=None):
        ctx = ctx or multiprocessing.get_context("spawn")
        self.sid = sid
        self.pending = False
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_worker_main, args=(child, spec),
                                daemon=True)
        self.proc.start()
        child.close()

    def wait_ready(self) -> None:
        tag, payload = self.conn.recv()
        if tag != "ready":
            raise RuntimeError(f"shard {self.sid}: {payload}")

    def _rpc(self, msg) -> Any:
        try:
            self.conn.send(msg)
            tag, payload = self.conn.recv()
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError) as e:
            raise ShardKilledError(
                f"shard {self.sid}: worker gone mid-{msg[0]} ({e!r})")
        if tag == "error":
            raise ShardKilledError(f"shard {self.sid}: {payload}")
        return payload

    def submit(self, req: Request) -> None:
        self._rpc(("submit", req))

    def load(self, snap: Dict[str, Any]) -> None:
        self._rpc(("load", snap))

    def snapshot(self) -> Dict[str, Any]:
        return self._rpc(("snapshot", None))

    def final_stats(self) -> Dict[str, Any]:
        return self._rpc(("stats", None))

    def reset_stats(self) -> None:
        self._rpc(("reset", None))

    def kill(self) -> None:
        self.proc.terminate()
        self.proc.join(timeout=30)

    def step_send(self, directive: Optional[Dict[str, Any]],
                  step: int) -> None:
        if not self.proc.is_alive():
            raise ShardKilledError(f"shard {self.sid}: worker process died")
        try:
            self.conn.send(("step", {"directive": directive, "step": step}))
        except (BrokenPipeError, OSError) as e:
            raise ShardKilledError(f"shard {self.sid}: pipe closed ({e!r})")

    def step_recv(self, timeout_s: float) -> Optional[Dict[str, Any]]:
        try:
            if not self.conn.poll(timeout_s):
                if not self.proc.is_alive():
                    raise ShardKilledError(
                        f"shard {self.sid}: worker died without replying")
                return None                        # missed heartbeat
            tag, payload = self.conn.recv()
        except ShardKilledError:
            raise
        except (EOFError, BrokenPipeError, ConnectionResetError, OSError) as e:
            raise ShardKilledError(f"shard {self.sid}: pipe closed ({e!r})")
        if tag == "error":
            raise ShardKilledError(f"shard {self.sid}: {payload}")
        return payload

    def close(self) -> None:
        if self.proc.is_alive():
            try:
                self.conn.send(("stop", None))
                self.proc.join(timeout=10)
            except (BrokenPipeError, OSError):
                pass
            if self.proc.is_alive():
                self.proc.terminate()
                self.proc.join(timeout=10)
        self.conn.close()


class ServeFleet:
    """N engine shards behind one ``submit/step/run`` facade.

    ``factory(sid) -> ServeEngine`` builds in-process shards;
    ``worker_spec`` (``{"engine": make_queue_engine kwargs, "env": {...}}``)
    builds ``mp`` workers instead. All shards must share one seed/config so
    any shard decodes any request byte-identically — that is what makes
    failover replay sound.
    """

    def __init__(self, factory: Optional[Callable[[int], Any]] = None, *,
                 shards: int = 2, backend: str = "inproc",
                 worker_spec: Optional[Dict[str, Any]] = None,
                 checkpoint_every: int = 1,
                 manifest_dir: Optional[str] = None,
                 miss_suspect: int = 2, miss_dead: int = 4,
                 heartbeat_timeout_s: float = 120.0,
                 chaos: Optional[ShardChaosConfig] = None,
                 max_replays: int = 2, seed: int = 0):
        assert backend in ("inproc", "mp"), backend
        assert shards >= 1
        self.n_shards = shards
        self.backend = backend
        self.seed = seed
        self.monitor = HealthMonitor(shards, miss_suspect=miss_suspect,
                                     miss_dead=miss_dead)
        self.dispatcher = Dispatcher(self.monitor)
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.checkpoint_every = max(int(checkpoint_every), 1)
        self.manifest_dir = manifest_dir
        self.max_replays = max_replays
        self.chaos = (ShardChaosMonkey(chaos, shards)
                      if chaos is not None and chaos.armed else None)
        self.completions: List[Completion] = []
        self.stats: Dict[str, Any] = {
            "fleet_steps": 0, "failovers": 0, "shard_lost": 0, "replays": 0,
            "checkpoints": 0, "heartbeat_misses": 0, "tokens_out": 0,
            "wall_seconds": 0.0, "error_completions": 0, "deadline_miss": 0,
        }
        self._requests: Dict[int, Request] = {}    # originals, for replay
        self._completed: set = set()               # exactly-one guard
        self._replays: Dict[int, int] = {}
        self._snaps: Dict[int, Dict[str, Any]] = {}
        self._failed_over: set = set()
        self._step_no = 0
        if backend == "inproc":
            assert factory is not None, "inproc backend needs a factory"
            self.shards: List[Any] = [InProcessShard(s, factory(s))
                                      for s in range(shards)]
        else:
            assert worker_spec is not None, "mp backend needs worker_spec"
            ctx = multiprocessing.get_context("spawn")
            # start every worker before waiting: engines build concurrently
            self.shards = [WorkerShard(s, worker_spec, ctx=ctx)
                           for s in range(shards)]
            for sh in self.shards:
                sh.wait_ready()

    # -- facade --------------------------------------------------------------
    def submit(self, request: Request) -> bool:
        """Route to the least-loaded healthy shard; emits an immediate typed
        ``shard_lost`` completion when the whole fleet is dead."""
        self._requests[request.uid] = request
        while True:
            sid = self.dispatcher.route(exclude=self._pending_sids())
            if sid is None and self.dispatcher.route() is not None:
                self._await_pending()     # only stalled-reply shards remain
                continue
            if sid is None:
                self._lost(request.uid, (),
                           "no live shard to route the request to")
                return False
            try:
                self.shards[sid].submit(request)
            except ShardKilledError as e:
                self._note_death(sid, self._step_no, str(e))
                continue
            self.dispatcher.assign(request.uid, sid)
            return True

    def step(self) -> bool:
        """One fleet round: dispatch a step to every routable shard, collect
        replies (heartbeats), fail over any death, checkpoint. Returns True
        while submitted requests are still outstanding."""
        step = self._step_no
        self._step_no += 1
        self.stats["fleet_steps"] += 1
        deaths: List[tuple] = []
        stepped: List[int] = []
        # phase 1: send — mp shards overlap their chunk compute
        for sid, shard in enumerate(self.shards):
            if not self.monitor.alive(sid):
                continue
            if shard.pending:            # last round's reply still owed
                stepped.append(sid)
                continue
            d = self.chaos.directive(sid, step) if self.chaos else None
            if d is not None and d["kind"] == "kill":
                shard.kill()             # inproc: arm; mp: real terminate()
                d = None                 # detection runs through step_send
            try:
                shard.step_send(d, step)
            except ShardKilledError as e:
                deaths.append((sid, str(e)))
                continue
            stepped.append(sid)
        # phase 2: collect
        for sid in stepped:
            death = self._collect(sid, step)
            if death is not None:
                deaths.append((sid, death))
        # phase 3: failover
        for sid, why in deaths:
            self._note_death(sid, step, why)
        # phase 4: periodic checkpoint of live shards
        if step % self.checkpoint_every == 0:
            self._checkpoint(step)
        return self.dispatcher.outstanding > 0

    def run(self, requests: Optional[List[Request]] = None,
            max_steps: Optional[int] = None) -> List[Completion]:
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.step():
            if max_steps is not None and self._step_no >= max_steps:
                break
        self.stats["wall_seconds"] += time.perf_counter() - t0
        self.stats["tokens_per_second"] = self.stats["tokens_out"] / max(
            self.stats["wall_seconds"], 1e-9)
        self.stats.update(suspects=self.monitor.suspects,
                          recoveries=self.monitor.recoveries,
                          deaths=self.monitor.deaths)
        return self.completions

    def close(self) -> None:
        for shard in self.shards:
            shard.close()

    # -- plumbing ------------------------------------------------------------
    def _pending_sids(self) -> set:
        return {s for s, sh in enumerate(self.shards) if sh.pending}

    def _collect(self, sid: int, step: int) -> Optional[str]:
        """Receive one shard's step reply; returns a death reason or None."""
        shard = self.shards[sid]
        try:
            r = shard.step_recv(self.heartbeat_timeout_s)
        except ShardKilledError as e:
            shard.pending = False
            return str(e)
        if r is None:                       # timeout: reply still owed
            shard.pending = True
            self.stats["heartbeat_misses"] += 1
            if self.monitor.miss(sid, step) is ShardState.DEAD:
                return "missed heartbeats"
            return None
        shard.pending = False
        self.dispatcher.note_reserved(sid, r.get("reserved", 0))
        self._drain(r.get("completions") or ())
        if r.get("beat", True):
            self.monitor.beat(sid, step)
        else:
            self.stats["heartbeat_misses"] += 1
            if self.monitor.miss(sid, step) is ShardState.DEAD:
                return "missed heartbeats"
        return None

    def _await_pending(self) -> None:
        """Block on shards that owe a reply (used when every routable shard
        is mid-step and a submit/failover needs a target)."""
        for sid in sorted(self._pending_sids()):
            if not self.monitor.alive(sid):
                continue
            death = self._collect(sid, self._step_no)
            if death is not None:
                self._note_death(sid, self._step_no, death)

    def _drain(self, comps) -> None:
        for c in comps:
            if c.uid in self._completed:    # zombie/dup replay guard
                continue
            self._completed.add(c.uid)
            self.dispatcher.complete(c.uid)
            self.completions.append(c)
            self.stats["tokens_out"] += int(
                np.asarray(c.tokens).reshape(-1).size)
            if c.finish_reason == "error":
                self.stats["error_completions"] += 1
                if c.reason == ErrorReason.DEADLINE.value:
                    self.stats["deadline_miss"] += 1

    def _lost(self, uid: int, partial, msg: str) -> None:
        """The one place a fleet-level failure becomes a Completion."""
        self.completions.append(Completion(
            uid=uid, tokens=np.asarray(partial, np.int32).reshape(-1),
            finish_reason="error", error=msg,
            reason=ErrorReason.SHARD_LOST.value))
        self._completed.add(uid)
        self.dispatcher.complete(uid)
        self.stats["shard_lost"] += 1
        self.stats["error_completions"] += 1

    def _checkpoint(self, step: int) -> None:
        for sid, shard in enumerate(self.shards):
            if not self.monitor.alive(sid) or shard.pending:
                continue
            try:
                snap = shard.snapshot()
            except ShardKilledError:
                continue                 # the next step notices the death
            self._snaps[sid] = snap
            self.stats["checkpoints"] += 1
            if self.manifest_dir:
                RestartManifest(
                    step=step, checkpoint_dir="", mesh_shape=[1],
                    mesh_axes=["data"], data_seed=self.seed,
                    shape=f"fleet-shard{sid}", serve=snap,
                ).save(os.path.join(self.manifest_dir, f"shard{sid}.json"))

    def _manifest_snap(self, sid: int) -> Optional[Dict[str, Any]]:
        if not self.manifest_dir:
            return None
        path = os.path.join(self.manifest_dir, f"shard{sid}.json")
        if not os.path.exists(path):
            return None
        return RestartManifest.load(path).serve

    def _note_death(self, sid: int, step: int, why: str) -> None:
        self.monitor.mark_dead(sid, step, why)
        if sid in self._failed_over:
            return
        self._failed_over.add(sid)
        self._failover(sid, step, why)

    def _failover(self, sid: int, step: int, why: str) -> None:
        """Re-drive a dead shard's outstanding requests on survivors from
        its last checkpoint. Requests finished-but-undrained in the snapshot
        deliver directly; snapshotted in-flight/queued ones resume (partial
        tokens preserved where the paged path allows); ones routed after the
        snapshot replay from the retained original Request. ``shard_lost``
        fires only when no survivor exists or the replay budget is spent."""
        self.stats["failovers"] += 1
        outstanding = self.dispatcher.fail_shard(sid)
        snap = self._manifest_snap(sid) or self._snaps.get(sid) or {}
        comp_by_uid = {c["uid"]: c for c in snap.get("completions") or ()}
        entry_by_uid = {d["uid"]: d
                        for d in list(snap.get("queued") or ())
                        + list(snap.get("active") or ())}
        for uid in outstanding:
            if uid in self._completed:
                continue
            if uid in comp_by_uid:       # done before death, reply lost
                c = comp_by_uid[uid]
                self._drain([Completion(
                    uid=uid, tokens=np.asarray(c["tokens"], np.int32),
                    finish_reason=c["finish_reason"], error=c.get("error"),
                    reason=c.get("reason"))])
                continue
            entry = entry_by_uid.get(uid)
            partial = [int(x) for x in (entry or {}).get("produced") or ()]
            self._replays[uid] = self._replays.get(uid, 0) + 1
            if self._replays[uid] > self.max_replays:
                self._lost(uid, partial,
                           f"shard {sid} died ({why}); replay budget "
                           f"({self.max_replays}) exhausted")
                continue
            placed = False
            while not placed:
                tgt = self.dispatcher.route(exclude=self._pending_sids())
                if tgt is None and self.dispatcher.route() is not None:
                    self._await_pending()
                    continue
                if tgt is None:
                    self._lost(uid, partial,
                               f"shard {sid} died ({why}); no survivor "
                               "to replay on")
                    break
                try:
                    if entry is not None:
                        self.shards[tgt].load({"queued": [entry]})
                    else:                # routed after the last checkpoint
                        self.shards[tgt].submit(self._requests[uid])
                except ShardKilledError as e:
                    self._note_death(tgt, step, str(e))
                    continue
                self.dispatcher.assign(uid, tgt)
                self.stats["replays"] += 1
                placed = True

    # -- instrumentation -----------------------------------------------------
    def reset_stats(self) -> None:
        """Zero the throughput counters after a warmup drain so a benchmark
        measures warm decode only (compile time excluded)."""
        for k in ("tokens_out", "error_completions", "deadline_miss"):
            self.stats[k] = 0
        self.stats["wall_seconds"] = 0.0
        for sid, shard in enumerate(self.shards):
            if self.monitor.alive(sid):
                try:
                    shard.reset_stats()
                except ShardKilledError:
                    pass

    def per_shard_stats(self) -> List[Dict[str, Any]]:
        """Per-shard serving stats (the per-channel stats registry of the
        PIMulator idiom): tok/s over decode-chunk wall time plus tail
        latency, one row per shard, dead or alive."""
        rows = []
        for sid, shard in enumerate(self.shards):
            s: Dict[str, Any] = {}
            if self.monitor.alive(sid) and not shard.pending:
                try:
                    s = shard.final_stats()
                except ShardKilledError:
                    s = {}
            cs = [float(x) for x in s.get("chunk_seconds") or ()]
            rows.append({
                "shard": sid, "state": str(self.monitor.state(sid)),
                "tokens_out": int(s.get("tokens_out", 0)),
                "dispatches": int(s.get("decode_dispatches", 0)),
                "tok_s": (s.get("tokens_out", 0) / max(sum(cs), 1e-9)
                          if cs else 0.0),
                "p50_ms": float(np.percentile(cs, 50)) * 1e3 if cs else 0.0,
                "p95_ms": float(np.percentile(cs, 95)) * 1e3 if cs else 0.0,
                "error_completions": int(s.get("error_completions", 0)),
                "deadline_miss": int(s.get("deadline_miss", 0)),
            })
        return rows

    @property
    def chaos_events(self) -> List[Dict[str, Any]]:
        return [] if self.chaos is None else list(self.chaos.events)
