"""Production mesh construction.

A function — not a module-level constant — so importing never touches JAX
device state. The dry-run process sets XLA_FLAGS for 512 host devices before
any JAX import; tests and benches see the real (1-device) platform.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(axes: Tuple[str, ...] = ("data",)) -> Mesh:
    """Mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    shape = (n,) + (1,) * (len(axes) - 1)
    return make_mesh(shape, axes)


def describe(mesh: Mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())


def n_chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
