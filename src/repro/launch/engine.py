"""Continuous-batching serving engine: slot-based queue over fused decode.

The DaPPA-style contract applied to serving: callers submit :class:`Request`s
and get :class:`Completion`s back — they never manage hardware slots, caches,
padding, or dispatch. Internally the engine keeps a fixed number of *slots*
(rows of one batched, pre-sized KV cache). Between fused decode chunks
(``make_generate_step``: one jit dispatch per ``chunk`` tokens) finished
sequences are swapped out and queued prompts are prefilled into the freed
slots. Every per-slot state (``pos``, ``pos_ids``, KV rows) is independent,
so sequences at different depths coexist in one cache.

With ``REPRO_KV_PAGES=<n>`` the KV cache is *paged*: fixed-size pages live in
one shared pool per leaf and each slot holds an int32 page table. A host-side
free-list allocator hands out pool rows on prefill and reclaims them on
retirement, so HBM committed to KV scales with tokens actually held, not with
``slots * max_len`` (the statically over-allocated layout the paper's MIMDRAM
line attacks in DRAM). Full prefill pages are hash-consed across slots
(prefix sharing, refcounted, copy-on-write before any divergent write), and
physical page 0 is a reserved trash page: retired slots point there, so their
stale in-flight decode writes land harmlessly.

All device programs have static shapes (slots x prompt_len x max_len x
chunk), so after the first chunk per shape everything is a compile-cache hit.

    PYTHONPATH=src python -m repro.launch.serve --mode queue --arch pimref-100m
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.core.mimdram import Plan
from repro.kernels.common import kv_page_size
from repro.launch import specs as specs_lib
from repro.launch.steps import make_serving_jits, spec_config
from repro.models.layers import PagedKVCache, QKVCache


@dataclass
class Request:
    """One generation request. ``tokens``: 1-D int32 prompt; prompts longer
    than the engine's prompt bucket are rejected with an ``error`` completion
    (never silently truncated), shorter ones are padded to the bucket.
    ``extras``: additional prefill inputs (e.g. ``patch_embeds``) shaped for
    batch=1 at the engine's prompt length."""

    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    extras: Optional[Dict[str, Any]] = None


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray            # generated token ids (1-D)
    finish_reason: str            # "length" | "eos" | "error"
    error: Optional[str] = None   # set when finish_reason == "error"


@dataclass
class _Slot:
    request: Request
    produced: List[int] = field(default_factory=list)
    n: int = 0                    # true prompt length (paged mode)
    cap: int = 0                  # per-request generation cap
    chunks: int = 0               # decode chunks dispatched since insert


class PromptTooLongError(ValueError):
    """Prompt exceeds the engine's prompt bucket (no silent truncation)."""


class _PageAllocator:
    """Host-side free-list allocator over the physical page pool.

    Pool row 0 is the trash page and is never handed out. ``refs`` counts how
    many slot-table entries point at each physical page; ``registry`` is the
    hash-cons map for prefix sharing: (logical page index, prefix-token
    bytes) -> physical page. Registered pages are freed (and unregistered)
    when their last reference drops — sharing is across *concurrent* slots.
    """

    def __init__(self, n_phys: int):
        self.n_phys = n_phys
        self.free: List[int] = list(range(n_phys - 1, 0, -1))
        self.refs = np.zeros(n_phys, np.int32)
        self.registry: Dict[Tuple[int, bytes], int] = {}
        self.reg_key: Dict[int, Tuple[int, bytes]] = {}
        self.hits = 0

    def alloc(self) -> int:
        phys = self.free.pop()
        self.refs[phys] = 1
        return phys

    def lookup(self, key: Tuple[int, bytes]) -> Optional[int]:
        phys = self.registry.get(key)
        if phys is not None:
            self.refs[phys] += 1
            self.hits += 1
        return phys

    def register(self, phys: int, key: Tuple[int, bytes]) -> None:
        self.registry[key] = phys
        self.reg_key[phys] = key

    def unregister(self, phys: int) -> None:
        key = self.reg_key.pop(phys, None)
        if key is not None:
            self.registry.pop(key, None)

    def decref(self, phys: int) -> None:
        self.refs[phys] -= 1
        if self.refs[phys] == 0:
            self.unregister(phys)
            self.free.append(phys)

    @property
    def used(self) -> int:
        return int((self.refs > 0).sum())


class ServeEngine:
    """Slot-based continuous batching over one fused-decode compiled program.

    Args:
      slots: number of concurrently decoded sequences (cache batch dim).
      prompt_len: prompt bucket; prompts are padded to this (left-padded in
        the contiguous layout, right-padded with true-length tracking in the
        paged layout) and rejected when longer.
      max_new: per-request generation cap (and cache sizing: max_len defaults
        to prompt_len + max_new).
      chunk: decode tokens per dispatch (the fused scan length).
      eos_id: stop token (None = length-only stopping).
      temperature/top_k: sampling knobs (0 temperature = greedy).
      spec/spec_k: speculative-decoding drafter ("off"|"ngram"|"draft") and
        draft length (default: the REPRO_SPEC_DECODE / REPRO_SPEC_K knobs).
        Transparent to callers — greedy completions are byte-identical with
        speculation on or off; stats gain spec_accepted_len_per_draft and a
        spec_accept_hist accepted-length histogram.
    """

    def __init__(self, model, params, plan: Plan, *, slots: int = 4,
                 prompt_len: int = 32, max_new: int = 32, chunk: int = 8,
                 max_len: Optional[int] = None, eos_id: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 spec: Optional[str] = None, spec_k: Optional[int] = None):
        self.model, self.params, self.plan = model, params, plan
        self.slots, self.prompt_len, self.chunk = slots, prompt_len, chunk
        self.max_new, self.eos_id = max_new, eos_id
        self.max_len = max_len or (prompt_len + max_new)
        assert self.max_len >= prompt_len + 1
        # speculative decoding: each fused-scan iteration verifies a
        # (spec_k+1)-token block, so a chunk can write chunk*(spec_k+1)
        # positions and the cache carries spec_k rows of k-ahead slack
        self.spec, self.spec_k = spec_config(model, spec, spec_k)
        self.chunk_span = chunk * (self.spec_k + 1) \
            if self.spec != "off" else chunk

        # big cache = batch-1 prefill cache zeros, tiled to `slots` rows
        shape1 = ShapeConfig("engine_prefill", seq_len=prompt_len,
                             global_batch=1, mode="prefill")
        small = specs_lib.prefill_cache_specs(
            model, model.cfg, shape1,
            self.max_len + (self.spec_k if self.spec != "off" else 0))
        paged_leaves = [l for l in jax.tree_util.tree_leaves(
            small, is_leaf=lambda x: isinstance(x, PagedKVCache))
            if isinstance(l, PagedKVCache)]
        self.paged = kv_page_size() > 0 and bool(paged_leaves)
        if self.paged:
            self.page_size = paged_leaves[0].page_size
            self.n_logical_pages = paged_leaves[0].table.shape[-1]
            self.cache_pos_len = self.page_size * self.n_logical_pages
            assert all(l.page_size == self.page_size
                       and l.table.shape[-1] == self.n_logical_pages
                       for l in paged_leaves), (
                "paged engine needs one shared (page_size, n_pages) across "
                "all paged cache leaves")

        self._prefill, self._generate, rep, cache_sh = make_serving_jits(
            model, plan, max_len=self.max_len, chunk=chunk,
            temperature=temperature, top_k=top_k, full_logits=self.paged,
            spec=self.spec, spec_k=self.spec_k)
        # family-aware prefill inputs: vlm reserves a patch prefix inside the
        # prompt bucket (shorter token field), audio needs src_embeds, etc.
        self._batch_template = specs_lib.input_specs(model.cfg, shape1)
        self._tok_len = self._batch_template["tokens"].shape[1]
        self._prefix_len = (self.prompt_len - self._tok_len
                            if model.cfg.family == "vlm" else 0)
        axes = model.cache_logical_axes()
        # -1 = no batch axis (leaf shared across slots; None breaks tree_map);
        # the string "paged" marks whole PagedKVCache leaves, which get pool
        # scatters + table-row writes instead of batch-row slicing.
        is_node = lambda x: isinstance(x, (tuple, PagedKVCache))
        self._batch_axis = jax.tree_util.tree_map(
            lambda ax: "paged" if isinstance(ax, PagedKVCache)
            else (ax.index("act_batch") if "act_batch" in ax else -1),
            axes, is_leaf=is_node)
        is_marked = lambda x: isinstance(x, (tuple, str)) or (
            isinstance(x, int) and not isinstance(x, bool))

        def tile(ax, sd):
            if isinstance(ax, str):          # paged: widen pool, zero tables
                n_phys = slots * self.n_logical_pages + 1

                def z(s, nd):
                    shp = list(s.shape)
                    shp[len(shp) - nd] = n_phys
                    return jnp.zeros(tuple(shp), s.dtype)

                pages = (QKVCache(z(sd.pages.codes, 4), z(sd.pages.scale, 3))
                         if isinstance(sd.pages, QKVCache)
                         else z(sd.pages, 4))
                tshp = list(sd.table.shape)
                tshp[-2] = slots
                return PagedKVCache(pages, jnp.zeros(tuple(tshp), jnp.int32))
            shp = list(sd.shape)
            if ax >= 0:
                shp[ax] = slots
            return jnp.zeros(tuple(shp), sd.dtype)

        self.cache = jax.tree_util.tree_map(tile, self._batch_axis, small,
                                            is_leaf=is_marked)
        self._tok = jnp.zeros((slots, 1), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        if self.spec != "off":
            # n-gram drafter history: committed prompt+emitted tokens per
            # slot, sized for the bucket + cap + within-chunk overshoot
            self.hist_cap = self._tok_len + self.max_new + self.chunk_span
            self._hist = jnp.zeros((slots, self.hist_cap), jnp.int32)
            self._hist_len = jnp.zeros((slots,), jnp.int32)
        if rep is not None:
            self.cache = jax.device_put(self.cache, cache_sh)
            self._tok = jax.device_put(self._tok, rep)
            self._key = jax.device_put(self._key, rep)
            if self.spec != "off":
                self._hist = jax.device_put(self._hist, rep)
                self._hist_len = jax.device_put(self._hist_len, rep)

        def pool_idx(bp, nd):
            # page axis sits nd-from-the-end: -4 for (.., P, ps, H, D) pools
            # and codes, -3 for (.., P, ps, H) scale pools
            return bp.ndim - nd

        def insert(big, tok, small_cache, first_tok, slot, dest_rows,
                   table_row, pos_val, hist=None, hist_len=None,
                   tok_row=None, n_tok=None):
            def put(ax, b, s):
                if isinstance(ax, str):      # paged leaf
                    def pp(bp, sp, nd):
                        a = pool_idx(bp, nd)
                        src = sp[(slice(None),) * a + (slice(1, None),)]
                        return bp.at[(slice(None),) * a + (dest_rows,)].set(
                            src.astype(bp.dtype))

                    pages = (QKVCache(pp(b.pages.codes, s.pages.codes, 4),
                                      pp(b.pages.scale, s.pages.scale, 3))
                             if isinstance(b.pages, QKVCache)
                             else pp(b.pages, s.pages, 4))
                    table = b.table.at[..., slot, :].set(table_row)
                    return PagedKVCache(pages, table)
                if ax < 0:
                    return b
                start = tuple(slot if j == ax else 0 for j in range(b.ndim))
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), start)

            big = jax.tree_util.tree_map(put, self._batch_axis, big,
                                         small_cache, is_leaf=is_marked)
            if self.paged and "pos" in big:
                # right-padded bucket prefill: decode resumes at the true
                # prompt end, not at the bucket length
                big["pos"] = big["pos"].at[slot].set(pos_val)
            tok = jax.lax.dynamic_update_slice(tok, first_tok, (slot, 0))
            if hist is None:
                return big, tok
            # seed the n-gram drafter from the prefill tokens already on
            # device (no extra host copy): rotate left-padded prompts so the
            # true tokens sit at hist[:n_tok], zero the stale tail
            row = tok_row[0].astype(jnp.int32)
            if not self.paged:               # left-padded contiguous bucket
                row = jnp.roll(row, n_tok - row.shape[0])
            full = jnp.zeros((self.hist_cap,), jnp.int32)
            full = full.at[:row.shape[0]].set(row)
            hist = jax.lax.dynamic_update_slice(hist, full[None, :], (slot, 0))
            hist_len = hist_len.at[slot].set(n_tok)
            return big, tok, hist, hist_len

        if self.spec != "off":
            self._insert = jax.jit(insert, donate_argnums=(0, 1, 8, 9),
                                   out_shardings=(cache_sh, rep, rep, rep))
        else:
            self._insert = jax.jit(insert, donate_argnums=(0, 1),
                                   out_shardings=(cache_sh, rep))

        if self.paged:
            def clear_slot(big, slot):
                def cl(ax, b):
                    if isinstance(ax, str):
                        return PagedKVCache(
                            b.pages, b.table.at[..., slot, :].set(0))
                    return b
                return jax.tree_util.tree_map(cl, self._batch_axis, big,
                                              is_leaf=is_marked)

            def cow(big, slot, logical_i, old_row, new_row):
                def c(ax, b):
                    if not isinstance(ax, str):
                        return b

                    def cp(bp, nd):
                        a = pool_idx(bp, nd)
                        row = jax.lax.dynamic_index_in_dim(
                            bp, old_row, axis=a, keepdims=False)
                        return bp.at[(slice(None),) * a + (new_row,)].set(row)

                    pages = (QKVCache(cp(b.pages.codes, 4),
                                      cp(b.pages.scale, 3))
                             if isinstance(b.pages, QKVCache)
                             else cp(b.pages, 4))
                    return PagedKVCache(
                        pages, b.table.at[..., slot, logical_i].set(new_row))
                return jax.tree_util.tree_map(c, self._batch_axis, big,
                                              is_leaf=is_marked)

            self._clear_slot = jax.jit(clear_slot, donate_argnums=(0,),
                                       out_shardings=cache_sh)
            self._cow = jax.jit(cow, donate_argnums=(0,),
                                out_shardings=cache_sh)
            self._alloc = _PageAllocator(slots * self.n_logical_pages + 1)
            self._host_table = np.zeros((slots, self.n_logical_pages),
                                        np.int32)
            # prefix sharing needs (a) pure-token prompts (patch/src extras
            # are not in the hash key) and (b) a cache long enough that the
            # bucket prefill never ring-wraps (page <-> position identity)
            self._share_ok = (set(self._batch_template) == {"tokens"}
                              and self.cache_pos_len >= self.prompt_len)

        self._queue: Deque[Request] = deque()
        self._active: Dict[int, _Slot] = {}
        self._free: List[int] = list(range(slots))[::-1]
        self.completions: List[Completion] = []
        # instrumentation for benchmarks / regression tracking
        self.stats: Dict[str, Any] = {
            "decode_dispatches": 0, "prefills": 0, "tokens_out": 0,
            "wall_seconds": 0.0, "chunk_seconds": [],
            "kv_pages_in_use": 0, "kv_pages_peak": 0, "prefix_hits": 0,
        }
        if self.spec != "off":
            # per-iteration accepted-length histogram: bin i = iterations
            # that committed i+1 tokens (1 fed + i accepted drafts)
            self.stats["spec_draft_iters"] = 0
            self.stats["spec_emitted_tokens"] = 0
            self.stats["spec_accept_hist"] = [0] * (self.spec_k + 1)
        if self.paged:
            self._page_bytes = sum(
                leaf.nbytes // leaf.shape[pool_idx(leaf, nd)]
                for pl in jax.tree_util.tree_leaves(
                    self.cache,
                    is_leaf=lambda x: isinstance(x, PagedKVCache))
                if isinstance(pl, PagedKVCache)
                for leaf, nd in (
                    [(pl.pages.codes, 4), (pl.pages.scale, 3)]
                    if isinstance(pl.pages, QKVCache) else [(pl.pages, 4)]))
            self.stats["kv_hbm_bytes"] = 0
        else:
            # contiguous baseline: KV HBM is committed statically up front
            def _kv_bytes(ax, leaf):
                leaves = jax.tree_util.tree_leaves(leaf)
                flat_ax = jax.tree_util.tree_leaves(
                    ax, is_leaf=lambda x: isinstance(x, tuple))
                return sum(l.nbytes for l, a in zip(leaves, flat_ax)
                           if "cache_seq" in a)

            self.stats["kv_hbm_bytes"] = sum(
                jax.tree_util.tree_leaves(jax.tree_util.tree_map(
                    _kv_bytes, axes, self.cache,
                    is_leaf=lambda x: isinstance(x, tuple))))
        self.stats["kv_hbm_bytes_peak"] = self.stats["kv_hbm_bytes"]

    # -- queue interface -----------------------------------------------------
    def submit(self, request: Request) -> None:
        self._queue.append(request)

    def _prefill_batch(
            self, req: Request) -> Tuple[Dict[str, Any], int, np.ndarray]:
        """Build the bucketed batch-1 prefill batch; returns (batch, n, t)
        with ``n`` the true prompt length inside the bucket (prefix + tokens)
        and ``t`` the flat int32 prompt (reused by the page planner — no
        second host copy of the request tokens).

        Over-long (or empty) prompts raise :class:`PromptTooLongError` /
        ``ValueError`` — the engine never silently truncates a prompt.
        """
        t = np.asarray(req.tokens, np.int32).reshape(-1)
        if len(t) > self._tok_len:
            raise PromptTooLongError(
                f"request {req.uid}: prompt has {len(t)} tokens, engine "
                f"bucket holds {self._tok_len} (submit shorter prompts or "
                "build the engine with a larger prompt_len)")
        n = self._prefix_len + len(t)
        if n < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        toks = np.zeros((1, self._tok_len), np.int32)
        if self.paged:
            toks[0, :len(t)] = t          # right-pad; decode overwrites pads
        else:
            toks[0, self._tok_len - len(t):] = t
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        for k, sd in self._batch_template.items():
            if k not in batch:
                raise ValueError(
                    f"request {req.uid}: family {self.model.cfg.family!r} "
                    f"needs extras[{k!r}] shaped {sd.shape}")
            if tuple(batch[k].shape) != sd.shape:
                raise ValueError(
                    f"request {req.uid}: input {k!r} has shape "
                    f"{tuple(batch[k].shape)}, engine bucket needs {sd.shape}")
        return batch, n, t

    def _plan_pages(self, slot: int, toks: np.ndarray, n: int,
                    cap: int) -> Tuple[np.ndarray, np.ndarray]:
        """Allocate this slot's logical pages; returns (dest_rows, table_row).

        Pages are claimed up front for every position the slot can touch —
        the prefill bucket plus ``cap`` decode steps plus within-chunk
        overrun — so decode never needs to grow the table. ``dest_rows`` is
        where the prefill insert scatters each small-cache page: the slot's
        own pool row, or the trash page (0) for pages resolved by prefix
        sharing (their content already exists) and for unallocated tails.
        """
        ps, NP, T = self.page_size, self.n_logical_pages, self.cache_pos_len
        # positions beyond maxp hold only prefill pad rows, which decode never
        # writes and always reads causally masked: their pages stay on trash.
        # chunk_span covers within-chunk overrun incl. speculative k-ahead
        # writes; anything past it lands on the trash page, affecting only
        # tokens beyond the cap (which retirement drops)
        maxp = n + cap - 1 + self.chunk_span  # one past the last writable pos
        n_alloc = min(-(-min(maxp, T) // ps), NP)
        dest = np.zeros(NP, np.int32)
        trow = np.zeros(NP, np.int32)
        for i in range(n_alloc):
            key = ((i, toks[:(i + 1) * ps].tobytes())
                   if self._share_ok and (i + 1) * ps <= n else None)
            phys = self._alloc.lookup(key) if key is not None else None
            if phys is None:
                phys = self._alloc.alloc()
                if key is not None:
                    self._alloc.register(phys, key)
                dest[i] = phys               # owned: prefill writes the page
            trow[i] = phys
        self._host_table[slot] = trow
        return dest, trow

    def _refresh_page_stats(self) -> None:
        used = self._alloc.used
        self.stats["kv_pages_in_use"] = used
        self.stats["kv_pages_peak"] = max(self.stats["kv_pages_peak"], used)
        self.stats["kv_hbm_bytes"] = used * self._page_bytes
        self.stats["kv_hbm_bytes_peak"] = max(
            self.stats["kv_hbm_bytes_peak"], self.stats["kv_hbm_bytes"])
        self.stats["prefix_hits"] = self._alloc.hits

    def _admit(self) -> None:
        while self._free and self._queue:
            req = self._queue.popleft()
            # build+validate the batch BEFORE claiming a slot: a malformed
            # request raises to the caller without leaking concurrency —
            # except over-long/empty prompts, which retire with an explicit
            # error completion so queue draining survives bad inputs
            try:
                batch, n, t = self._prefill_batch(req)
            except (PromptTooLongError, ValueError) as e:
                self.completions.append(Completion(
                    uid=req.uid, tokens=np.zeros((0,), np.int32),
                    finish_reason="error", error=str(e)))
                continue
            slot = self._free.pop()
            logits, small = self._prefill(self.params, batch)
            if self.paged:
                cap = min(req.max_new_tokens, self.max_len - n)
                first = jnp.argmax(logits[:, n - 1]).reshape(1, 1) \
                    .astype(jnp.int32)
                dest, trow = self._plan_pages(slot, t, n, cap)
                args = (self.cache, self._tok, small, first, jnp.int32(slot),
                        jnp.asarray(dest), jnp.asarray(trow), jnp.int32(n))
                self._active[slot] = _Slot(request=req, n=n, cap=cap)
            else:
                cap = min(req.max_new_tokens, self.max_len - self.prompt_len)
                first = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                args = (self.cache, self._tok, small, first, jnp.int32(slot),
                        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                        jnp.int32(0))
                self._active[slot] = _Slot(request=req, n=n, cap=cap)
            if self.spec != "off":
                (self.cache, self._tok, self._hist,
                 self._hist_len) = self._insert(
                    *args, self._hist, self._hist_len, batch["tokens"],
                    jnp.int32(len(t)))
            else:
                self.cache, self._tok = self._insert(*args)
            if self.paged:
                self._refresh_page_stats()
            self.stats["prefills"] += 1

    def _ensure_writable(self) -> None:
        """Copy-on-write pass before a decode chunk: any page the chunk may
        write that is shared (refs > 1) gets copied to a fresh pool row, and
        sole-owned pages still in the prefix registry are unregistered —
        the first divergent write never lands on another slot's prefix."""
        ps, T = self.page_size, self.cache_pos_len
        for slot, st in self._active.items():
            # surviving slots always satisfy device pos = n + len(produced):
            # EOS-truncated and cap-clamped slots retire at chunk end, so the
            # host count is exact for every slot still decoding (speculative
            # rollback rewinds pos to the committed length the same way)
            pos0 = st.n + len(st.produced)
            pages = {(p % T) // ps
                     for p in range(pos0, pos0 + self.chunk_span)}
            for i in sorted(pages):
                phys = int(self._host_table[slot, i])
                if phys == 0:
                    continue                  # unallocated tail -> trash sink
                if self._alloc.refs[phys] > 1:
                    new = self._alloc.alloc()
                    self.cache = self._cow(
                        self.cache, jnp.int32(slot), jnp.int32(i),
                        jnp.int32(phys), jnp.int32(new))
                    self._alloc.refs[phys] -= 1
                    self._host_table[slot, i] = new
                elif phys in self._alloc.reg_key:
                    self._alloc.unregister(phys)

    def step(self) -> bool:
        """Admit waiting requests, run one fused decode chunk, retire finished
        slots. Returns False when fully drained.

        EOS detection ran on device inside the fused chunk (the scan carries
        a per-slot ``done`` flag and a valid-token count), so retirement here
        is a per-slot slice — no host-side scan over the token buffer."""
        self._admit()
        if not self._active:
            return bool(self._queue)
        if self.paged:
            self._ensure_writable()
            self._refresh_page_stats()
        t0 = time.perf_counter()
        eos = jnp.int32(-1 if self.eos_id is None else self.eos_id)
        if self.spec != "off":
            (self.cache, self._tok, self._key, done, n_valid, toks,
             self._hist, self._hist_len, acc) = self._generate(
                self.params, self.cache, self._tok, self._key, eos,
                self._hist, self._hist_len)
        else:
            (self.cache, self._tok, self._key, done, n_valid,
             toks) = self._generate(self.params, self.cache, self._tok,
                                    self._key, eos)
        toks_np = np.asarray(toks)          # ONE host sync per chunk
        done_np = np.asarray(done)
        n_np = np.asarray(n_valid)
        self.stats["chunk_seconds"].append(time.perf_counter() - t0)
        self.stats["decode_dispatches"] += 1
        if self.spec != "off":
            # accepted-length stats over live iterations of active slots only
            # (free/retired slots ride the fused chunk and emit garbage rows)
            acc_np = np.asarray(acc)[sorted(self._active)]
            live = acc_np[acc_np >= 0]
            self.stats["spec_draft_iters"] += int(live.size)
            self.stats["spec_emitted_tokens"] += int(live.sum())
            for c, freq in zip(*np.unique(live, return_counts=True)):
                self.stats["spec_accept_hist"][int(c) - 1] += int(freq)
        for slot in list(self._active):
            st = self._active[slot]
            st.chunks += 1
            take = min(int(n_np[slot]), st.cap - len(st.produced))
            st.produced.extend(int(t) for t in toks_np[slot][:take])
            if bool(done_np[slot]) and take == int(n_np[slot]):
                self._retire(slot, "eos")
            elif len(st.produced) >= st.cap:
                self._retire(slot, "length")
        return bool(self._active or self._queue)

    def _retire(self, slot: int, reason: str) -> None:
        st = self._active.pop(slot)
        self._free.append(slot)
        if self.paged:
            for phys in self._host_table[slot]:
                if phys:
                    self._alloc.decref(int(phys))
            self._host_table[slot] = 0
            # device table -> trash page: the retired slot keeps riding the
            # fused decode until reused, and its stale writes must not land
            # in pages the allocator may hand to someone else
            self.cache = self._clear_slot(self.cache, jnp.int32(slot))
            self._refresh_page_stats()
        self.stats["tokens_out"] += len(st.produced)
        self.completions.append(Completion(
            uid=st.request.uid, tokens=np.asarray(st.produced, np.int32),
            finish_reason=reason))

    def run(self, requests: Optional[List[Request]] = None) -> List[Completion]:
        """Drain the queue (plus ``requests``); returns all completions."""
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.step():
            pass
        # stats are cumulative across run() calls (the engine is reusable)
        self.stats["wall_seconds"] += time.perf_counter() - t0
        self.stats["tokens_per_second"] = self.stats["tokens_out"] / max(
            self.stats["wall_seconds"], 1e-9)
        self.stats["dispatches_per_token"] = (
            self.stats["decode_dispatches"] / max(self.stats["tokens_out"], 1))
        self.stats["kv_bytes_per_token"] = (
            self.stats["kv_hbm_bytes_peak"] / max(self.stats["tokens_out"], 1))
        if self.spec != "off":
            # mean tokens committed per draft-verify iteration (1.0 = nothing
            # accepted, spec_k+1 = every draft + bonus accepted)
            self.stats["spec_accepted_len_per_draft"] = (
                self.stats["spec_emitted_tokens"]
                / max(self.stats["spec_draft_iters"], 1))
        return self.completions

    def compile_cache_size(self) -> Optional[int]:
        """Compiled-program count of the fused generate step (1 after warmup
        means no recompilation). None when the JAX version has no probe."""
        probe = getattr(self._generate, "_cache_size", None)
        return probe() if callable(probe) else None
