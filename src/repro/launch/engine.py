"""Continuous-batching serving engine: slot-based queue over fused decode.

The DaPPA-style contract applied to serving: callers submit :class:`Request`s
and get :class:`Completion`s back — they never manage hardware slots, caches,
padding, or dispatch. Internally the engine keeps a fixed number of *slots*
(rows of one batched, pre-sized KV cache). Between fused decode chunks
(``make_generate_step``: one jit dispatch per ``chunk`` tokens) finished
sequences are swapped out and queued prompts are prefilled into the freed
slots. Every per-slot state (``pos``, ``pos_ids``, KV rows) is independent,
so sequences at different depths coexist in one cache.

With ``REPRO_KV_PAGES=<n>`` the KV cache is *paged*: fixed-size pages live in
one shared pool per leaf and each slot holds an int32 page table. A host-side
free-list allocator hands out pool rows on prefill and reclaims them on
retirement, so HBM committed to KV scales with tokens actually held, not with
``slots * max_len`` (the statically over-allocated layout the paper's MIMDRAM
line attacks in DRAM). Full prefill pages are hash-consed across slots
(prefix sharing, refcounted, copy-on-write before any divergent write), and
physical page 0 is a reserved trash page: retired slots point there, so their
stale in-flight decode writes land harmlessly.

All device programs have static shapes (slots x prompt_len x max_len x
chunk), so after the first chunk per shape everything is a compile-cache hit.

Fault tolerance (the Proteus runtime-engine contract — adapt, don't crash):
admission control reserves worst-case KV pages per request up front so
demand allocation/COW can never exhaust the pool mid-decode; a bounded
frontend queue rejects overflow with ``queue_full`` completions; per-request
deadlines retire expired work; an on-device finite guard in the fused scan
quarantines exactly the slot whose logits went non-finite; transient
pre-dispatch failures retry with backoff; a StragglerMonitor watchdog on the
chunk dispatch sheds load (speculation off, then smaller chunks) under
sustained pressure; and ``snapshot()``/``load_snapshot()`` round-trip the
queue + per-slot progress through a ``RestartManifest`` for
preemption-safe serving. Every submitted request ends in exactly one
:class:`Completion` — success or a typed error ``reason``.

    PYTHONPATH=src python -m repro.launch.serve --mode queue --arch pimref-100m
"""
from __future__ import annotations

import enum
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.core.mimdram import Plan
from repro.distributed.chaos import (ChaosConfig, ChaosMonkey,
                                     TransientStepError, nan_logits_hook)
from repro.distributed.fault_tolerance import StragglerMonitor
from repro.kernels.common import kv_page_size
from repro.launch import specs as specs_lib
from repro.launch.steps import (make_generate_step, make_serving_jits,
                                spec_config)
from repro.models.layers import PagedKVCache, QKVCache


class ErrorReason(str, enum.Enum):
    """Typed ``Completion.reason`` values — the engine's failure model.

    Shared by the engine, the serve CLI, and the bench columns; see the
    README "Robust serving" table for which fault maps to which reason.
    """

    PROMPT_TOO_LONG = "prompt_too_long"   # prompt exceeds the engine bucket
    BAD_REQUEST = "bad_request"           # empty prompt / malformed extras
    QUEUE_FULL = "queue_full"             # bounded frontend queue overflow
    DEADLINE = "deadline"                 # per-request deadline expired
    PAGE_POOL = "page_pool"               # KV page pool cannot hold request
    NAN_LOGITS = "nan_logits"             # finite guard quarantined the slot
    STEP_FAILURE = "step_failure"         # chunk dispatch failed (post-retry)
    SHARD_LOST = "shard_lost"             # fleet shard died, replay impossible

    def __str__(self) -> str:             # log/CSV-friendly
        return self.value


@dataclass
class Request:
    """One generation request. ``tokens``: 1-D int32 prompt; prompts longer
    than the engine's prompt bucket are rejected with an ``error`` completion
    (never silently truncated), shorter ones are padded to the bucket.
    ``extras``: additional prefill inputs (e.g. ``patch_embeds``) shaped for
    batch=1 at the engine's prompt length. ``deadline_ms``: wall-clock budget
    from submission; expiry retires the request with a ``deadline`` error
    completion carrying whatever tokens were produced."""

    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    extras: Optional[Dict[str, Any]] = None
    deadline_ms: Optional[float] = None


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray            # generated token ids (1-D; may be partial
                                  # on error — e.g. deadline/nan quarantine)
    finish_reason: str            # "length" | "eos" | "error"
    error: Optional[str] = None   # set when finish_reason == "error"
    reason: Optional[str] = None  # ErrorReason value when error, else None


@dataclass
class _Slot:
    request: Request
    produced: List[int] = field(default_factory=list)
    n: int = 0                    # true prompt length (paged mode)
    cap: int = 0                  # per-request generation cap
    chunks: int = 0               # decode chunks dispatched since insert


class PromptTooLongError(ValueError):
    """Prompt exceeds the engine's prompt bucket (no silent truncation)."""


class PagePoolExhaustedError(RuntimeError):
    """KV page pool has no free physical page.

    With admission reservation this is defense-in-depth: the engine only
    admits a request when its worst-case page demand fits alongside every
    active slot's reservation, so only external pressure (the chaos
    harness stealing pages, or an allocator bug) can trigger it. The engine
    catches it and retires the offending request with a ``page_pool`` error
    completion; other slots keep draining.
    """

    def __init__(self, alloc: "_PageAllocator", what: str):
        self.pool_stats = {
            "n_phys": alloc.n_phys, "free": len(alloc.free),
            "used": alloc.used, "registered": len(alloc.registry),
        }
        super().__init__(
            f"KV page pool exhausted during {what}: "
            f"{self.pool_stats['used']}/{alloc.n_phys - 1} pages in use, "
            f"{self.pool_stats['free']} free, "
            f"{self.pool_stats['registered']} prefix-registered")


class _PageAllocator:
    """Host-side free-list allocator over the physical page pool.

    Pool row 0 is the trash page and is never handed out. ``refs`` counts how
    many slot-table entries point at each physical page; ``registry`` is the
    hash-cons map for prefix sharing: (logical page index, prefix-token
    bytes) -> physical page. Registered pages are freed (and unregistered)
    when their last reference drops — sharing is across *concurrent* slots.
    """

    def __init__(self, n_phys: int):
        self.n_phys = n_phys
        self.free: List[int] = list(range(n_phys - 1, 0, -1))
        self.refs = np.zeros(n_phys, np.int32)
        self.registry: Dict[Tuple[int, bytes], int] = {}
        self.reg_key: Dict[int, Tuple[int, bytes]] = {}
        self.hits = 0

    def alloc(self, what: str = "alloc") -> int:
        if not self.free:
            raise PagePoolExhaustedError(self, what)
        phys = self.free.pop()
        self.refs[phys] = 1
        return phys

    def lookup(self, key: Tuple[int, bytes]) -> Optional[int]:
        phys = self.registry.get(key)
        if phys is not None:
            self.refs[phys] += 1
            self.hits += 1
        return phys

    def register(self, phys: int, key: Tuple[int, bytes]) -> None:
        self.registry[key] = phys
        self.reg_key[phys] = key

    def unregister(self, phys: int) -> None:
        key = self.reg_key.pop(phys, None)
        if key is not None:
            self.registry.pop(key, None)

    def decref(self, phys: int) -> None:
        self.refs[phys] -= 1
        if self.refs[phys] == 0:
            self.unregister(phys)
            self.free.append(phys)

    @property
    def used(self) -> int:
        return int((self.refs > 0).sum())


class ServeEngine:
    """Slot-based continuous batching over one fused-decode compiled program.

    Args:
      slots: number of concurrently decoded sequences (cache batch dim).
      prompt_len: prompt bucket; prompts are padded to this (left-padded in
        the contiguous layout, right-padded with true-length tracking in the
        paged layout) and rejected when longer.
      max_new: per-request generation cap (and cache sizing: max_len defaults
        to prompt_len + max_new).
      chunk: decode tokens per dispatch (the fused scan length).
      eos_id: stop token (None = length-only stopping).
      temperature/top_k: sampling knobs (0 temperature = greedy).
      spec/spec_k: speculative-decoding drafter ("off"|"ngram"|"draft") and
        draft length (default: the REPRO_SPEC_DECODE / REPRO_SPEC_K knobs).
        Transparent to callers — greedy completions are byte-identical with
        speculation on or off; stats gain spec_accepted_len_per_draft and a
        spec_accept_hist accepted-length histogram.
      max_queue: bound on the *waiting* queue (active slots are separate);
        submissions past it complete immediately with a ``queue_full`` error.
        None = unbounded (the pre-robustness behavior).
      deadline_ms: default wall-clock budget applied to requests that do not
        carry their own ``Request.deadline_ms``. None = no deadline.
      page_pool_pages: physical KV pages in the paged pool (default
        ``slots * n_logical_pages``, the worst case — admission then never
        blocks on pages). Smaller pools make the page-reservation admission
        control load-bearing: requests wait until their worst-case page
        demand fits alongside every active slot's reservation.
      chaos: a :class:`~repro.distributed.chaos.ChaosConfig` arming the
        deterministic fault-injection harness for this engine.
      max_retries/retry_backoff_s: chunk-level retry budget for transient
        pre-dispatch failures (a retry never replays a dispatch whose
        donated operands are consumed; real dispatch exceptions fail over to
        ``step_failure`` completions for everything in flight).
      straggler_threshold/shed_after: the chunk-dispatch watchdog —
        chunks slower than ``threshold x`` the wall-time EMA are straggler
        events, and ``shed_after`` *consecutive* events shed load one level
        (speculation off, then chunk halved). Greedy output is
        byte-identical across shed levels, so shedding is invisible except
        in latency and ``stats``.
      clock: monotonic-seconds callable for deadlines (tests inject a fake).
    """

    def __init__(self, model, params, plan: Plan, *, slots: int = 4,
                 prompt_len: int = 32, max_new: int = 32, chunk: int = 8,
                 max_len: Optional[int] = None, eos_id: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 spec: Optional[str] = None, spec_k: Optional[int] = None,
                 max_queue: Optional[int] = None,
                 deadline_ms: Optional[float] = None,
                 page_pool_pages: Optional[int] = None,
                 chaos: Optional[ChaosConfig] = None,
                 max_retries: int = 2, retry_backoff_s: float = 0.02,
                 straggler_threshold: float = 3.0, shed_after: int = 2,
                 clock: Optional[Callable[[], float]] = None):
        self.model, self.params, self.plan = model, params, plan
        self.slots, self.prompt_len, self.chunk = slots, prompt_len, chunk
        self.max_new, self.eos_id = max_new, eos_id
        self.max_len = max_len or (prompt_len + max_new)
        assert self.max_len >= prompt_len + 1
        self.max_queue, self.deadline_ms = max_queue, deadline_ms
        self.max_retries, self.retry_backoff_s = max_retries, retry_backoff_s
        self.shed_after = shed_after
        self._clock = clock or time.monotonic
        self._chaos = ChaosMonkey(chaos) if chaos is not None else None
        self._straggler = StragglerMonitor(threshold=straggler_threshold,
                                           warmup_steps=3)
        self.seed = seed
        self._temperature, self._top_k = temperature, top_k
        self._dead = False
        # speculative decoding: each fused-scan iteration verifies a
        # (spec_k+1)-token block, so a chunk can write chunk*(spec_k+1)
        # positions and the cache carries spec_k rows of k-ahead slack
        self.spec, self.spec_k = spec_config(model, spec, spec_k)
        self.chunk_span = chunk * (self.spec_k + 1) \
            if self.spec != "off" else chunk

        # big cache = batch-1 prefill cache zeros, tiled to `slots` rows
        shape1 = ShapeConfig("engine_prefill", seq_len=prompt_len,
                             global_batch=1, mode="prefill")
        small = specs_lib.prefill_cache_specs(
            model, model.cfg, shape1,
            self.max_len + (self.spec_k if self.spec != "off" else 0))
        paged_leaves = [l for l in jax.tree_util.tree_leaves(
            small, is_leaf=lambda x: isinstance(x, PagedKVCache))
            if isinstance(l, PagedKVCache)]
        self.paged = kv_page_size() > 0 and bool(paged_leaves)
        if self.paged:
            self.page_size = paged_leaves[0].page_size
            self.n_logical_pages = paged_leaves[0].table.shape[-1]
            self.cache_pos_len = self.page_size * self.n_logical_pages
            assert all(l.page_size == self.page_size
                       and l.table.shape[-1] == self.n_logical_pages
                       for l in paged_leaves), (
                "paged engine needs one shared (page_size, n_pages) across "
                "all paged cache leaves")
            # +1: physical page 0 is the reserved trash page
            self.n_phys_pages = (slots * self.n_logical_pages
                                 if page_pool_pages is None
                                 else int(page_pool_pages)) + 1

        # chaos NaN injection compiles a logits hook into the fused scan;
        # arming is per-dispatch data (arm[slot] = poison position, -1 =
        # disarmed), so clean dispatches stay bitwise-identical
        self._hook = (nan_logits_hook if self._chaos is not None
                      and self._chaos.cfg.wants_nan else None)
        self._prefill, self._generate, rep, cache_sh = make_serving_jits(
            model, plan, max_len=self.max_len, chunk=chunk,
            temperature=temperature, top_k=top_k, full_logits=self.paged,
            spec=self.spec, spec_k=self.spec_k, logits_hook=self._hook)
        self._rep, self._cache_sh = rep, cache_sh
        self._arm_np = np.full((slots,), -1, np.int32)
        # load shedding swaps in degraded generate programs (built lazily);
        # self._generate stays the warmed level-0 program
        self._spec_live, self._chunk_live = self.spec, chunk
        self._gen_shed = None
        # family-aware prefill inputs: vlm reserves a patch prefix inside the
        # prompt bucket (shorter token field), audio needs src_embeds, etc.
        self._batch_template = specs_lib.input_specs(model.cfg, shape1)
        self._tok_len = self._batch_template["tokens"].shape[1]
        self._prefix_len = (self.prompt_len - self._tok_len
                            if model.cfg.family == "vlm" else 0)
        axes = model.cache_logical_axes()
        # -1 = no batch axis (leaf shared across slots; None breaks tree_map);
        # the string "paged" marks whole PagedKVCache leaves, which get pool
        # scatters + table-row writes instead of batch-row slicing.
        is_node = lambda x: isinstance(x, (tuple, PagedKVCache))
        self._batch_axis = jax.tree_util.tree_map(
            lambda ax: "paged" if isinstance(ax, PagedKVCache)
            else (ax.index("act_batch") if "act_batch" in ax else -1),
            axes, is_leaf=is_node)
        is_marked = lambda x: isinstance(x, (tuple, str)) or (
            isinstance(x, int) and not isinstance(x, bool))

        def tile(ax, sd):
            if isinstance(ax, str):          # paged: widen pool, zero tables
                n_phys = self.n_phys_pages

                def z(s, nd):
                    shp = list(s.shape)
                    shp[len(shp) - nd] = n_phys
                    return jnp.zeros(tuple(shp), s.dtype)

                pages = (QKVCache(z(sd.pages.codes, 4), z(sd.pages.scale, 3))
                         if isinstance(sd.pages, QKVCache)
                         else z(sd.pages, 4))
                tshp = list(sd.table.shape)
                tshp[-2] = slots
                return PagedKVCache(pages, jnp.zeros(tuple(tshp), jnp.int32))
            shp = list(sd.shape)
            if ax >= 0:
                shp[ax] = slots
            return jnp.zeros(tuple(shp), sd.dtype)

        self.cache = jax.tree_util.tree_map(tile, self._batch_axis, small,
                                            is_leaf=is_marked)
        self._tok = jnp.zeros((slots, 1), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        if self.spec != "off":
            # n-gram drafter history: committed prompt+emitted tokens per
            # slot, sized for the bucket + cap + within-chunk overshoot
            self.hist_cap = self._tok_len + self.max_new + self.chunk_span
            self._hist = jnp.zeros((slots, self.hist_cap), jnp.int32)
            self._hist_len = jnp.zeros((slots,), jnp.int32)
        if rep is not None:
            self.cache = jax.device_put(self.cache, cache_sh)
            self._tok = jax.device_put(self._tok, rep)
            self._key = jax.device_put(self._key, rep)
            if self.spec != "off":
                self._hist = jax.device_put(self._hist, rep)
                self._hist_len = jax.device_put(self._hist_len, rep)

        def pool_idx(bp, nd):
            # page axis sits nd-from-the-end: -4 for (.., P, ps, H, D) pools
            # and codes, -3 for (.., P, ps, H) scale pools
            return bp.ndim - nd

        def insert(big, tok, small_cache, first_tok, slot, dest_rows,
                   table_row, pos_val, hist=None, hist_len=None,
                   tok_row=None, n_tok=None):
            def put(ax, b, s):
                if isinstance(ax, str):      # paged leaf
                    def pp(bp, sp, nd):
                        a = pool_idx(bp, nd)
                        src = sp[(slice(None),) * a + (slice(1, None),)]
                        return bp.at[(slice(None),) * a + (dest_rows,)].set(
                            src.astype(bp.dtype))

                    pages = (QKVCache(pp(b.pages.codes, s.pages.codes, 4),
                                      pp(b.pages.scale, s.pages.scale, 3))
                             if isinstance(b.pages, QKVCache)
                             else pp(b.pages, s.pages, 4))
                    table = b.table.at[..., slot, :].set(table_row)
                    return PagedKVCache(pages, table)
                if ax < 0:
                    return b
                start = tuple(slot if j == ax else 0 for j in range(b.ndim))
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), start)

            big = jax.tree_util.tree_map(put, self._batch_axis, big,
                                         small_cache, is_leaf=is_marked)
            if self.paged and "pos" in big:
                # right-padded bucket prefill: decode resumes at the true
                # prompt end, not at the bucket length
                big["pos"] = big["pos"].at[slot].set(pos_val)
            tok = jax.lax.dynamic_update_slice(tok, first_tok, (slot, 0))
            if hist is None:
                return big, tok
            # seed the n-gram drafter from the prefill tokens already on
            # device (no extra host copy): rotate left-padded prompts so the
            # true tokens sit at hist[:n_tok], zero the stale tail
            row = tok_row[0].astype(jnp.int32)
            if not self.paged:               # left-padded contiguous bucket
                row = jnp.roll(row, n_tok - row.shape[0])
            full = jnp.zeros((self.hist_cap,), jnp.int32)
            full = full.at[:row.shape[0]].set(row)
            hist = jax.lax.dynamic_update_slice(hist, full[None, :], (slot, 0))
            hist_len = hist_len.at[slot].set(n_tok)
            return big, tok, hist, hist_len

        if self.spec != "off":
            self._insert = jax.jit(insert, donate_argnums=(0, 1, 8, 9),
                                   out_shardings=(cache_sh, rep, rep, rep))
        else:
            self._insert = jax.jit(insert, donate_argnums=(0, 1),
                                   out_shardings=(cache_sh, rep))

        if self.paged:
            def clear_slot(big, slot):
                def cl(ax, b):
                    if isinstance(ax, str):
                        return PagedKVCache(
                            b.pages, b.table.at[..., slot, :].set(0))
                    return b
                return jax.tree_util.tree_map(cl, self._batch_axis, big,
                                              is_leaf=is_marked)

            def cow(big, slot, logical_i, old_row, new_row):
                def c(ax, b):
                    if not isinstance(ax, str):
                        return b

                    def cp(bp, nd):
                        a = pool_idx(bp, nd)
                        row = jax.lax.dynamic_index_in_dim(
                            bp, old_row, axis=a, keepdims=False)
                        return bp.at[(slice(None),) * a + (new_row,)].set(row)

                    pages = (QKVCache(cp(b.pages.codes, 4),
                                      cp(b.pages.scale, 3))
                             if isinstance(b.pages, QKVCache)
                             else cp(b.pages, 4))
                    return PagedKVCache(
                        pages, b.table.at[..., slot, logical_i].set(new_row))
                return jax.tree_util.tree_map(c, self._batch_axis, big,
                                              is_leaf=is_marked)

            self._clear_slot = jax.jit(clear_slot, donate_argnums=(0,),
                                       out_shardings=cache_sh)
            self._cow = jax.jit(cow, donate_argnums=(0,),
                                out_shardings=cache_sh)
            self._alloc = _PageAllocator(self.n_phys_pages)
            self._host_table = np.zeros((slots, self.n_logical_pages),
                                        np.int32)
            # prefix sharing needs (a) pure-token prompts (patch/src extras
            # are not in the hash key) and (b) a cache long enough that the
            # bucket prefill never ring-wraps (page <-> position identity)
            self._share_ok = (set(self._batch_template) == {"tokens"}
                              and self.cache_pos_len >= self.prompt_len)

        self._queue: Deque[Request] = deque()
        self._active: Dict[int, _Slot] = {}
        self._free: List[int] = list(range(slots))[::-1]
        self.completions: List[Completion] = []
        # admission reservation: worst-case pages per active slot; the sum
        # never exceeds the usable pool, so demand alloc/COW cannot exhaust
        self._reserved: Dict[int, int] = {}
        self._reserved_total = 0
        self._deadline_at: Dict[int, float] = {}     # uid -> absolute clock
        self._resume_prefix: Dict[int, List[int]] = {}   # restored progress
        self._pressure = 0                           # consecutive stragglers
        # instrumentation for benchmarks / regression tracking
        self.stats: Dict[str, Any] = {
            "decode_dispatches": 0, "prefills": 0, "tokens_out": 0,
            "wall_seconds": 0.0, "chunk_seconds": [],
            "kv_pages_in_use": 0, "kv_pages_peak": 0, "prefix_hits": 0,
            "deadline_miss": 0, "shed_events": 0, "retries": 0,
            "error_completions": 0, "straggler_events": 0,
            "admission_blocked": 0, "queue_peak": 0,
        }
        if self.spec != "off":
            # per-iteration accepted-length histogram: bin i = iterations
            # that committed i+1 tokens (1 fed + i accepted drafts)
            self.stats["spec_draft_iters"] = 0
            self.stats["spec_emitted_tokens"] = 0
            self.stats["spec_accept_hist"] = [0] * (self.spec_k + 1)
        if self.paged:
            self._page_bytes = sum(
                leaf.nbytes // leaf.shape[pool_idx(leaf, nd)]
                for pl in jax.tree_util.tree_leaves(
                    self.cache,
                    is_leaf=lambda x: isinstance(x, PagedKVCache))
                if isinstance(pl, PagedKVCache)
                for leaf, nd in (
                    [(pl.pages.codes, 4), (pl.pages.scale, 3)]
                    if isinstance(pl.pages, QKVCache) else [(pl.pages, 4)]))
            self.stats["kv_hbm_bytes"] = 0
        else:
            # contiguous baseline: KV HBM is committed statically up front
            def _kv_bytes(ax, leaf):
                leaves = jax.tree_util.tree_leaves(leaf)
                flat_ax = jax.tree_util.tree_leaves(
                    ax, is_leaf=lambda x: isinstance(x, tuple))
                return sum(l.nbytes for l, a in zip(leaves, flat_ax)
                           if "cache_seq" in a)

            self.stats["kv_hbm_bytes"] = sum(
                jax.tree_util.tree_leaves(jax.tree_util.tree_map(
                    _kv_bytes, axes, self.cache,
                    is_leaf=lambda x: isinstance(x, tuple))))
        self.stats["kv_hbm_bytes_peak"] = self.stats["kv_hbm_bytes"]

    # -- queue interface -----------------------------------------------------
    def _error(self, uid: int, tokens, reason: ErrorReason,
               msg: str) -> None:
        """Append a typed error completion (the only error path — keeps the
        exactly-one-Completion invariant auditable)."""
        self.completions.append(Completion(
            uid=uid, tokens=np.asarray(tokens, np.int32).reshape(-1),
            finish_reason="error", error=msg, reason=reason.value))
        self.stats["error_completions"] += 1
        self._deadline_at.pop(uid, None)

    def submit(self, request: Request) -> bool:
        """Enqueue a request; returns False (with an immediate ``queue_full``
        error completion) when the bounded frontend queue is full."""
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self._error(request.uid, (), ErrorReason.QUEUE_FULL,
                        f"request {request.uid}: queue full "
                        f"({len(self._queue)}/{self.max_queue} waiting)")
            return False
        dl = (request.deadline_ms if request.deadline_ms is not None
              else self.deadline_ms)
        if dl is not None:
            self._deadline_at[request.uid] = self._clock() + dl / 1e3
        self._queue.append(request)
        self.stats["queue_peak"] = max(self.stats["queue_peak"],
                                       len(self._queue))
        return True

    def _expired(self, uid: int) -> bool:
        at = self._deadline_at.get(uid)
        return at is not None and self._clock() >= at

    def _expire_deadlines(self) -> None:
        """Retire queued and in-flight requests whose deadline passed.

        Runs at the top of every step: a queued request never occupies a
        slot after expiry, and an active one returns its partial tokens with
        a ``deadline`` error completion (freeing the slot and its pages)."""
        if not self._deadline_at:
            return
        keep: Deque[Request] = deque()
        for req in self._queue:
            if self._expired(req.uid):
                self.stats["deadline_miss"] += 1
                self._error(req.uid, (), ErrorReason.DEADLINE,
                            f"request {req.uid}: deadline expired while "
                            "queued")
            else:
                keep.append(req)
        self._queue = keep
        for slot in list(self._active):
            if self._expired(self._active[slot].request.uid):
                self.stats["deadline_miss"] += 1
                self._retire(slot, "error", reason=ErrorReason.DEADLINE,
                             error="deadline expired during decode")

    def _prefill_batch(
            self, req: Request) -> Tuple[Dict[str, Any], int, np.ndarray]:
        """Build the bucketed batch-1 prefill batch; returns (batch, n, t)
        with ``n`` the true prompt length inside the bucket (prefix + tokens)
        and ``t`` the flat int32 prompt (reused by the page planner — no
        second host copy of the request tokens).

        Over-long (or empty) prompts raise :class:`PromptTooLongError` /
        ``ValueError`` — the engine never silently truncates a prompt.
        """
        t = np.asarray(req.tokens, np.int32).reshape(-1)
        if len(t) > self._tok_len:
            raise PromptTooLongError(
                f"request {req.uid}: prompt has {len(t)} tokens, engine "
                f"bucket holds {self._tok_len} (submit shorter prompts or "
                "build the engine with a larger prompt_len)")
        n = self._prefix_len + len(t)
        if n < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        toks = np.zeros((1, self._tok_len), np.int32)
        if self.paged:
            toks[0, :len(t)] = t          # right-pad; decode overwrites pads
        else:
            toks[0, self._tok_len - len(t):] = t
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        for k, sd in self._batch_template.items():
            if k not in batch:
                raise ValueError(
                    f"request {req.uid}: family {self.model.cfg.family!r} "
                    f"needs extras[{k!r}] shaped {sd.shape}")
            if tuple(batch[k].shape) != sd.shape:
                raise ValueError(
                    f"request {req.uid}: input {k!r} has shape "
                    f"{tuple(batch[k].shape)}, engine bucket needs {sd.shape}")
        return batch, n, t

    def _plan_pages(self, slot: int, toks: np.ndarray, n: int,
                    cap: int) -> Tuple[np.ndarray, np.ndarray]:
        """Allocate this slot's logical pages; returns (dest_rows, table_row).

        Pages are claimed up front for every position the slot can touch —
        the prefill bucket plus ``cap`` decode steps plus within-chunk
        overrun — so decode never needs to grow the table. ``dest_rows`` is
        where the prefill insert scatters each small-cache page: the slot's
        own pool row, or the trash page (0) for pages resolved by prefix
        sharing (their content already exists) and for unallocated tails.
        """
        ps, NP, T = self.page_size, self.n_logical_pages, self.cache_pos_len
        dest = np.zeros(NP, np.int32)
        trow = np.zeros(NP, np.int32)
        claimed: List[int] = []
        try:
            for i in range(self._worst_pages(n, cap)):
                key = ((i, toks[:(i + 1) * ps].tobytes())
                       if self._share_ok and (i + 1) * ps <= n else None)
                phys = self._alloc.lookup(key) if key is not None else None
                if phys is None:
                    phys = self._alloc.alloc("prefill page planning")
                    if key is not None:
                        self._alloc.register(phys, key)
                    dest[i] = phys           # owned: prefill writes the page
                claimed.append(phys)
                trow[i] = phys
        except PagePoolExhaustedError:
            for phys in claimed:             # roll back shares and claims
                self._alloc.decref(phys)
            raise
        self._host_table[slot] = trow
        return dest, trow

    def _worst_pages(self, n: int, cap: int) -> int:
        """Worst-case physical pages a request can ever touch: the prefill
        bucket plus ``cap`` decode steps plus within-chunk overrun (the
        trailing prefill-pad positions stay on the trash page). Admission
        reserves this many; COW only converts shared pages to private ones,
        which the reservation already double-counts, so the sum of
        reservations bounds true page demand."""
        ps, NP, T = self.page_size, self.n_logical_pages, self.cache_pos_len
        # positions beyond maxp hold only prefill pad rows, which decode never
        # writes and always reads causally masked: their pages stay on trash.
        # chunk_span covers within-chunk overrun incl. speculative k-ahead
        # writes; anything past it lands on the trash page, affecting only
        # tokens beyond the cap (which retirement drops)
        maxp = n + cap - 1 + self.chunk_span  # one past the last writable pos
        return min(-(-min(maxp, T) // ps), NP)

    def _refresh_page_stats(self) -> None:
        used = self._alloc.used
        self.stats["kv_pages_in_use"] = used
        self.stats["kv_pages_peak"] = max(self.stats["kv_pages_peak"], used)
        self.stats["kv_hbm_bytes"] = used * self._page_bytes
        self.stats["kv_hbm_bytes_peak"] = max(
            self.stats["kv_hbm_bytes_peak"], self.stats["kv_hbm_bytes"])
        self.stats["prefix_hits"] = self._alloc.hits
        # routing signal for the fleet dispatcher's least-loaded tiebreak
        self.stats["kv_pages_reserved"] = self._reserved_total

    def _admit(self) -> None:
        while self._free and self._queue:
            req = self._queue[0]
            # build+validate the batch BEFORE claiming a slot: a malformed
            # request raises to the caller without leaking concurrency —
            # except over-long/empty/misshaped prompts, which retire with an
            # explicit error completion so queue draining survives bad inputs
            try:
                batch, n, t = self._prefill_batch(req)
            except PromptTooLongError as e:
                self._queue.popleft()
                self._error(req.uid, (), ErrorReason.PROMPT_TOO_LONG, str(e))
                continue
            except ValueError as e:
                self._queue.popleft()
                self._error(req.uid, (), ErrorReason.BAD_REQUEST, str(e))
                continue
            if self.paged:
                cap = min(req.max_new_tokens, self.max_len - n)
                need = self._worst_pages(n, cap)
                capacity = self.n_phys_pages - 1
                if need > capacity:
                    self._queue.popleft()
                    self._error(
                        req.uid, (), ErrorReason.PAGE_POOL,
                        f"request {req.uid}: needs {need} KV pages, pool "
                        f"holds {capacity} (shrink the request or grow "
                        "page_pool_pages)")
                    continue
                if self._reserved_total + need > capacity:
                    # backpressure: hold the request until retirements free
                    # reservations — never admit into possible exhaustion
                    self.stats["admission_blocked"] += 1
                    break
            self._queue.popleft()
            slot = self._free.pop()
            logits, small = self._prefill(self.params, batch)
            if self.paged:
                first = jnp.argmax(logits[:, n - 1]).reshape(1, 1) \
                    .astype(jnp.int32)
                try:
                    dest, trow = self._plan_pages(slot, t, n, cap)
                except PagePoolExhaustedError as e:
                    # reachable only under external pressure (chaos steal):
                    # the reservation invariant covers engine-driven demand
                    self._free.append(slot)
                    self._error(req.uid, (), ErrorReason.PAGE_POOL, str(e))
                    continue
                self._reserved[slot] = need
                self._reserved_total += need
                args = (self.cache, self._tok, small, first, jnp.int32(slot),
                        jnp.asarray(dest), jnp.asarray(trow), jnp.int32(n))
                self._active[slot] = _Slot(request=req, n=n, cap=cap)
            else:
                cap = min(req.max_new_tokens, self.max_len - self.prompt_len)
                first = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                args = (self.cache, self._tok, small, first, jnp.int32(slot),
                        jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.int32),
                        jnp.int32(0))
                self._active[slot] = _Slot(request=req, n=n, cap=cap)
            if self.spec != "off":
                (self.cache, self._tok, self._hist,
                 self._hist_len) = self._insert(
                    *args, self._hist, self._hist_len, batch["tokens"],
                    jnp.int32(len(t)))
            else:
                self.cache, self._tok = self._insert(*args)
            if self._hook is not None:
                # absolute logits positions: true prompt end (paged
                # right-pad) vs bucket end (contiguous left-pad)
                base = n if self.paged else self.prompt_len
                pos = self._chaos.plan_request(req.uid, base, cap)
                self._arm_np[slot] = -1 if pos is None else pos
            if self.paged:
                self._refresh_page_stats()
            self.stats["prefills"] += 1

    def _ensure_writable(self) -> None:
        """Copy-on-write pass before a decode chunk: any page the chunk may
        write that is shared (refs > 1) gets copied to a fresh pool row, and
        sole-owned pages still in the prefix registry are unregistered —
        the first divergent write never lands on another slot's prefix."""
        ps, T = self.page_size, self.cache_pos_len
        for slot, st in list(self._active.items()):
            # surviving slots always satisfy device pos = n + len(produced):
            # EOS-truncated and cap-clamped slots retire at chunk end, so the
            # host count is exact for every slot still decoding (speculative
            # rollback rewinds pos to the committed length the same way)
            pos0 = st.n + len(st.produced)
            pages = {(p % T) // ps
                     for p in range(pos0, pos0 + self.chunk_span)}
            try:
                for i in sorted(pages):
                    phys = int(self._host_table[slot, i])
                    if phys == 0:
                        continue              # unallocated tail -> trash sink
                    if self._alloc.refs[phys] > 1:
                        new = self._alloc.alloc("copy-on-write")
                        self.cache = self._cow(
                            self.cache, jnp.int32(slot), jnp.int32(i),
                            jnp.int32(phys), jnp.int32(new))
                        self._alloc.refs[phys] -= 1
                        self._host_table[slot, i] = new
                    elif phys in self._alloc.reg_key:
                        self._alloc.unregister(phys)
            except PagePoolExhaustedError as e:
                # reachable only under external page pressure (reservation
                # covers engine-driven COW): quarantine this slot — its
                # partial tokens return with a typed error, its freed pages
                # let the remaining slots keep draining
                self._retire(slot, "error", reason=ErrorReason.PAGE_POOL,
                             error=str(e))

    def step(self) -> bool:
        """Admit waiting requests, run one fused decode chunk, retire finished
        slots. Returns False when fully drained.

        EOS detection ran on device inside the fused chunk (the scan carries
        a per-slot ``done`` flag and a valid-token count), so retirement here
        is a per-slot slice — no host-side scan over the token buffer."""
        if self._dead:
            return False
        idx = self.stats["decode_dispatches"]        # chunk index
        if self._chaos is not None and self.paged:
            self._chaos.page_pressure(self._alloc, idx)
        self._expire_deadlines()
        self._admit()
        if not self._active:
            return bool(self._queue)
        if self.paged:
            self._ensure_writable()
            self._refresh_page_stats()
            if not self._active:                     # COW quarantine emptied
                return bool(self._queue)
        # the watchdog window opens before fault handling: injected slow
        # chunks and retry backoff are exactly the stalls a straggler
        # monitor must see
        self._straggler.step_start()
        # transient faults fire BEFORE the dispatch and retry with backoff;
        # the dispatch itself is never replayed (its donated operands are
        # consumed), so a real dispatch exception fails everything over
        attempt = 0
        while self._chaos is not None:
            try:
                self._chaos.on_chunk(idx)
                break
            except TransientStepError as e:
                attempt += 1
                self.stats["retries"] += 1
                if attempt > self.max_retries:
                    self._fail_all(f"transient failure persisted past "
                                   f"{self.max_retries} retries: {e}")
                    return False
                time.sleep(self.retry_backoff_s * attempt)
        t0 = time.perf_counter()
        eos = jnp.int32(-1 if self.eos_id is None else self.eos_id)
        spec_live = self._spec_live != "off"
        gen = self._gen_shed if self._gen_shed is not None else self._generate
        args = (self.params, self.cache, self._tok, self._key, eos)
        if spec_live:
            args += (self._hist, self._hist_len)
        if self._hook is not None:
            args += (jnp.asarray(self._arm_np),)
        try:
            out = gen(*args)
        except Exception as e:  # noqa: BLE001 — donated operands consumed
            self._fail_all(f"chunk dispatch failed: {e!r}")
            return False
        if spec_live:
            (self.cache, self._tok, self._key, done, n_valid, toks,
             self._hist, self._hist_len, acc, failed) = out
        else:
            (self.cache, self._tok, self._key, done, n_valid, toks,
             failed) = out
        toks_np = np.asarray(toks)          # ONE host sync per chunk
        done_np = np.asarray(done)
        n_np = np.asarray(n_valid)
        failed_np = np.asarray(failed)
        self.stats["chunk_seconds"].append(time.perf_counter() - t0)
        self.stats["decode_dispatches"] += 1
        # watchdog: chunk dispatches slower than threshold x the wall-time
        # EMA are straggler events; `shed_after` consecutive events shed one
        # load level (speculation -> off, then chunk halved)
        if self._straggler.step_end(idx) is not None:
            self.stats["straggler_events"] += 1
            self._pressure += 1
            if self._pressure >= self.shed_after:
                self._shed()
                self._pressure = 0
        else:
            self._pressure = 0
        if spec_live:
            # accepted-length stats over live iterations of active slots only
            # (free/retired slots ride the fused chunk and emit garbage rows)
            acc_np = np.asarray(acc)[sorted(self._active)]
            live = acc_np[acc_np >= 0]
            self.stats["spec_draft_iters"] += int(live.size)
            self.stats["spec_emitted_tokens"] += int(live.sum())
            for c, freq in zip(*np.unique(live, return_counts=True)):
                self.stats["spec_accept_hist"][int(c) - 1] += int(freq)
        for slot in list(self._active):
            st = self._active[slot]
            st.chunks += 1
            take = min(int(n_np[slot]), st.cap - len(st.produced))
            st.produced.extend(int(t) for t in toks_np[slot][:take])
            if bool(done_np[slot]) and take == int(n_np[slot]):
                self._retire(slot, "eos")
            elif len(st.produced) >= st.cap:
                self._retire(slot, "length")
            elif bool(failed_np[slot]):
                # finite guard tripped on device: quarantine exactly this
                # slot — n_valid stopped at the last token sampled from
                # finite logits, so `produced` is the clean prefix
                self._retire(slot, "error", reason=ErrorReason.NAN_LOGITS,
                             error=f"non-finite logits after "
                                   f"{len(st.produced)} tokens; slot "
                                   "quarantined")
        return bool(self._active or self._queue)

    def _retire(self, slot: int, finish: str, *,
                reason: Optional[ErrorReason] = None,
                error: Optional[str] = None) -> None:
        st = self._active.pop(slot)
        self._free.append(slot)
        self._arm_np[slot] = -1
        self._reserved_total -= self._reserved.pop(slot, 0)
        if self.paged:
            for phys in self._host_table[slot]:
                if phys:
                    self._alloc.decref(int(phys))
            self._host_table[slot] = 0
            # device table -> trash page: the retired slot keeps riding the
            # fused decode until reused, and its stale writes must not land
            # in pages the allocator may hand to someone else (skipped when
            # the engine is dead — the cache buffers may be gone)
            if not self._dead:
                self.cache = self._clear_slot(self.cache, jnp.int32(slot))
            self._refresh_page_stats()
        self.stats["tokens_out"] += len(st.produced)
        uid = st.request.uid
        self._deadline_at.pop(uid, None)
        produced = st.produced
        pre = self._resume_prefix.pop(uid, None)
        if pre:
            # restored request: tokens produced before the preemption were
            # re-prefilled as prompt suffix; the completion carries the full
            # stream so restore is invisible to callers
            produced = pre + produced
        if finish == "error":
            self._error(uid, produced, reason or ErrorReason.STEP_FAILURE,
                        error or "unknown failure")
        else:
            self.completions.append(Completion(
                uid=uid, tokens=np.asarray(produced, np.int32),
                finish_reason=finish))

    def _fail_all(self, msg: str) -> None:
        """Unrecoverable dispatch failure: every in-flight and queued request
        completes with a typed ``step_failure`` error (partial tokens for
        active slots) and the engine goes dead — the exactly-one-Completion
        invariant survives even a poisoned jit."""
        self._dead = True
        for slot in list(self._active):
            self._retire(slot, "error", reason=ErrorReason.STEP_FAILURE,
                         error=msg)
        while self._queue:
            req = self._queue.popleft()
            self._error(req.uid, (), ErrorReason.STEP_FAILURE, msg)

    def _shed(self) -> None:
        """Load shedding, one level per call: (1) speculation off, (2) chunk
        halved (repeatable down to 1 token/dispatch). Greedy token streams
        are byte-identical across levels — the degraded program resumes from
        the same per-slot cache/pos/tok state at the chunk boundary — so
        shedding trades only latency mechanics, never output."""
        if self._spec_live != "off":
            self._spec_live = "off"
        elif self._chunk_live > 1:
            self._chunk_live = max(self._chunk_live // 2, 1)
        else:
            return
        self.stats["shed_events"] += 1
        gen_fn = make_generate_step(
            self.model, self.plan, chunk=self._chunk_live,
            temperature=self._temperature, top_k=self._top_k,
            spec="off", spec_k=0, logits_hook=self._hook)
        self._gen_shed = jax.jit(
            gen_fn, donate_argnums=(1,),
            out_shardings=(self._cache_sh,) + (self._rep,) * 6)

    # -- checkpoint / restore ------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable serving state for a ``RestartManifest``: every
        not-yet-completed request (queued, or mid-decode with the tokens
        produced so far) plus the completions already emitted. Device state
        is deliberately NOT captured — restore re-prefills — so checkpoints
        stay tiny and layout/mesh-agnostic."""
        def entry(req: Request, produced: List[int]) -> Dict[str, Any]:
            toks = np.asarray(req.tokens, np.int32).reshape(-1).tolist()
            pre = self._resume_prefix.get(req.uid)
            if pre:      # already-restored request: split back to original
                produced = list(pre) + produced
                toks = toks[:len(toks) - len(pre)]
            d = {"uid": req.uid, "tokens": toks,
                 "max_new_tokens": int(req.max_new_tokens) + (len(pre or ())),
                 "produced": [int(x) for x in produced]}
            if req.deadline_ms is not None:
                d["deadline_ms"] = float(req.deadline_ms)
            return d

        return {
            "seed": self.seed,
            "temperature": self._temperature,
            "queued": [entry(r, []) for r in self._queue],
            "active": [entry(self._active[s].request,
                             list(self._active[s].produced))
                       for s in sorted(self._active)],
            "completions": [
                {"uid": c.uid, "tokens": np.asarray(c.tokens).tolist(),
                 "finish_reason": c.finish_reason, "error": c.error,
                 "reason": c.reason}
                for c in self.completions],
        }

    def load_snapshot(self, snap: Dict[str, Any],
                      resume: Optional[bool] = None) -> None:
        """Restore a :meth:`snapshot`: completions replay verbatim; queued
        and in-flight requests are resubmitted. With ``resume`` (default:
        paged layout + greedy sampling) an in-flight request re-prefills
        ``prompt + produced`` and decodes only the remainder — sound in the
        paged layout because right-padded prefill positions are
        bucket-independent, so the committed tokens reproduce the exact
        decode-time positions (the engine's ``prompt_len`` must fit the
        grown prompts). The contiguous layout left-pads to the bucket
        (absolute positions shift with prompt length), so it regenerates
        from scratch instead — greedy completions are byte-identical to an
        uninterrupted run either way."""
        if resume is None:
            resume = self.paged and self._temperature <= 0
        for c in snap.get("completions", ()):
            self.completions.append(Completion(
                uid=c["uid"], tokens=np.asarray(c["tokens"], np.int32),
                finish_reason=c["finish_reason"], error=c.get("error"),
                reason=c.get("reason")))
        for d in list(snap.get("queued", ())) + list(snap.get("active", ())):
            produced = [int(x) for x in d.get("produced") or ()]
            prompt = [int(x) for x in d["tokens"]]
            if resume and produced:
                self._resume_prefix[d["uid"]] = produced
                req = Request(
                    uid=d["uid"],
                    tokens=np.asarray(prompt + produced, np.int32),
                    max_new_tokens=d["max_new_tokens"] - len(produced),
                    deadline_ms=d.get("deadline_ms"))
            else:
                req = Request(uid=d["uid"],
                              tokens=np.asarray(prompt, np.int32),
                              max_new_tokens=d["max_new_tokens"],
                              deadline_ms=d.get("deadline_ms"))
            self.submit(req)

    def run(self, requests: Optional[List[Request]] = None, *,
            stop: Optional[Callable[[], bool]] = None) -> List[Completion]:
        """Drain the queue (plus ``requests``); returns all completions.

        ``stop`` is polled between chunks (e.g. a PreemptionHandler's
        ``requested`` flag): when it fires, draining halts at the chunk
        boundary with in-flight state intact — call :meth:`snapshot` next.
        """
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.step():
            if stop is not None and stop():
                break
        # stats are cumulative across run() calls (the engine is reusable)
        self.stats["wall_seconds"] += time.perf_counter() - t0
        self.stats["tokens_per_second"] = self.stats["tokens_out"] / max(
            self.stats["wall_seconds"], 1e-9)
        self.stats["dispatches_per_token"] = (
            self.stats["decode_dispatches"] / max(self.stats["tokens_out"], 1))
        self.stats["kv_bytes_per_token"] = (
            self.stats["kv_hbm_bytes_peak"] / max(self.stats["tokens_out"], 1))
        if self.spec != "off":
            # mean tokens committed per draft-verify iteration (1.0 = nothing
            # accepted, spec_k+1 = every draft + bonus accepted)
            self.stats["spec_accepted_len_per_draft"] = (
                self.stats["spec_emitted_tokens"]
                / max(self.stats["spec_draft_iters"], 1))
        return self.completions

    @property
    def chaos_events(self) -> List[Dict[str, Any]]:
        """Injection log of the attached chaos harness ([] when unarmed)."""
        return [] if self._chaos is None else list(self._chaos.events)

    def compile_cache_size(self) -> Optional[int]:
        """Compiled-program count of the fused generate step (1 after warmup
        means no recompilation). None when the JAX version has no probe."""
        probe = getattr(self._generate, "_cache_size", None)
        return probe() if callable(probe) else None
