"""Continuous-batching serving engine: slot-based queue over fused decode.

The DaPPA-style contract applied to serving: callers submit :class:`Request`s
and get :class:`Completion`s back — they never manage hardware slots, caches,
padding, or dispatch. Internally the engine keeps a fixed number of *slots*
(rows of one batched, pre-sized KV cache). Between fused decode chunks
(``make_generate_step``: one jit dispatch per ``chunk`` tokens) finished
sequences are swapped out and queued prompts are prefilled into the freed
slots. Every per-slot state (``pos``, ``pos_ids``, KV rows) is independent,
so sequences at different depths coexist in one cache.

All device programs have static shapes (slots x prompt_len x max_len x
chunk), so after the first chunk per shape everything is a compile-cache hit.

    PYTHONPATH=src python -m repro.launch.serve --mode queue --arch pimref-100m
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.core.mimdram import Plan
from repro.launch import specs as specs_lib
from repro.launch.steps import make_serving_jits


@dataclass
class Request:
    """One generation request. ``tokens``: 1-D int32 prompt (longer prompts
    are truncated to the engine's prompt_len bucket, shorter are left-padded).
    ``extras``: additional prefill inputs (e.g. ``patch_embeds``) shaped for
    batch=1 at the engine's prompt length."""

    uid: int
    tokens: np.ndarray
    max_new_tokens: int
    extras: Optional[Dict[str, Any]] = None


@dataclass
class Completion:
    uid: int
    tokens: np.ndarray            # generated token ids (1-D)
    finish_reason: str            # "length" | "eos"


@dataclass
class _Slot:
    request: Request
    produced: List[int] = field(default_factory=list)


class ServeEngine:
    """Slot-based continuous batching over one fused-decode compiled program.

    Args:
      slots: number of concurrently decoded sequences (cache batch dim).
      prompt_len: prompt bucket; prompts are left-padded/truncated to this.
      max_new: per-request generation cap (and cache sizing: max_len defaults
        to prompt_len + max_new).
      chunk: decode tokens per dispatch (the fused scan length).
      eos_id: stop token (None = length-only stopping).
      temperature/top_k: sampling knobs (0 temperature = greedy).
    """

    def __init__(self, model, params, plan: Plan, *, slots: int = 4,
                 prompt_len: int = 32, max_new: int = 32, chunk: int = 8,
                 max_len: Optional[int] = None, eos_id: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0):
        self.model, self.params, self.plan = model, params, plan
        self.slots, self.prompt_len, self.chunk = slots, prompt_len, chunk
        self.max_new, self.eos_id = max_new, eos_id
        self.max_len = max_len or (prompt_len + max_new)
        assert self.max_len >= prompt_len + 1

        self._prefill, self._generate, rep, cache_sh = make_serving_jits(
            model, plan, max_len=self.max_len, chunk=chunk,
            temperature=temperature, top_k=top_k)

        # big cache = batch-1 prefill cache zeros, tiled to `slots` rows
        shape1 = ShapeConfig("engine_prefill", seq_len=prompt_len,
                             global_batch=1, mode="prefill")
        small = specs_lib.prefill_cache_specs(model, model.cfg, shape1,
                                              self.max_len)
        # family-aware prefill inputs: vlm reserves a patch prefix inside the
        # prompt bucket (shorter token field), audio needs src_embeds, etc.
        self._batch_template = specs_lib.input_specs(model.cfg, shape1)
        self._tok_len = self._batch_template["tokens"].shape[1]
        axes = model.cache_logical_axes()
        # -1 = no batch axis (leaf shared across slots; None breaks tree_map)
        self._batch_axis = jax.tree_util.tree_map(
            lambda ax: ax.index("act_batch") if "act_batch" in ax else -1,
            axes, is_leaf=lambda x: isinstance(x, tuple))

        def tile(ax, sd):
            shp = list(sd.shape)
            if ax >= 0:
                shp[ax] = slots
            return jnp.zeros(tuple(shp), sd.dtype)

        self.cache = jax.tree_util.tree_map(tile, self._batch_axis, small)
        self._tok = jnp.zeros((slots, 1), jnp.int32)
        self._key = jax.random.PRNGKey(seed)
        if rep is not None:
            self.cache = jax.device_put(self.cache, cache_sh)
            self._tok = jax.device_put(self._tok, rep)
            self._key = jax.device_put(self._key, rep)

        def insert(big, tok, small_cache, first_tok, slot):
            def put(ax, b, s):
                if ax < 0:
                    return b
                start = tuple(slot if j == ax else 0 for j in range(b.ndim))
                return jax.lax.dynamic_update_slice(
                    b, s.astype(b.dtype), start)

            big = jax.tree_util.tree_map(put, self._batch_axis, big,
                                         small_cache)
            tok = jax.lax.dynamic_update_slice(tok, first_tok, (slot, 0))
            return big, tok

        self._insert = jax.jit(insert, donate_argnums=(0, 1),
                               out_shardings=(cache_sh, rep))

        self._queue: Deque[Request] = deque()
        self._active: Dict[int, _Slot] = {}
        self._free: List[int] = list(range(slots))[::-1]
        self.completions: List[Completion] = []
        # instrumentation for benchmarks / regression tracking
        self.stats: Dict[str, Any] = {
            "decode_dispatches": 0, "prefills": 0, "tokens_out": 0,
            "wall_seconds": 0.0, "chunk_seconds": [],
        }

    # -- queue interface -----------------------------------------------------
    def submit(self, request: Request) -> None:
        self._queue.append(request)

    def _prefill_batch(self, req: Request) -> Dict[str, Any]:
        toks = np.zeros((1, self._tok_len), np.int32)
        t = np.asarray(req.tokens, np.int32)[-self._tok_len:]
        toks[0, self._tok_len - len(t):] = t
        batch: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        for k, sd in self._batch_template.items():
            if k not in batch:
                raise ValueError(
                    f"request {req.uid}: family {self.model.cfg.family!r} "
                    f"needs extras[{k!r}] shaped {sd.shape}")
            if tuple(batch[k].shape) != sd.shape:
                raise ValueError(
                    f"request {req.uid}: input {k!r} has shape "
                    f"{tuple(batch[k].shape)}, engine bucket needs {sd.shape}")
        return batch

    def _admit(self) -> None:
        while self._free and self._queue:
            req = self._queue.popleft()
            # build+validate the batch BEFORE claiming a slot: a malformed
            # request raises to the caller without leaking concurrency
            batch = self._prefill_batch(req)
            slot = self._free.pop()
            logits, small = self._prefill(self.params, batch)
            first = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            self.cache, self._tok = self._insert(
                self.cache, self._tok, small, first, jnp.int32(slot))
            self._active[slot] = _Slot(request=req)
            self.stats["prefills"] += 1

    def step(self) -> bool:
        """Admit waiting requests, run one fused decode chunk, retire finished
        slots. Returns False when fully drained.

        EOS detection ran on device inside the fused chunk (the scan carries
        a per-slot ``done`` flag and a valid-token count), so retirement here
        is a per-slot slice — no host-side scan over the token buffer."""
        self._admit()
        if not self._active:
            return False
        t0 = time.perf_counter()
        eos = jnp.int32(-1 if self.eos_id is None else self.eos_id)
        (self.cache, self._tok, self._key, done, n_valid,
         toks) = self._generate(self.params, self.cache, self._tok,
                                self._key, eos)
        toks_np = np.asarray(toks)          # ONE host sync per chunk
        done_np = np.asarray(done)
        n_np = np.asarray(n_valid)
        self.stats["chunk_seconds"].append(time.perf_counter() - t0)
        self.stats["decode_dispatches"] += 1
        for slot in list(self._active):
            st = self._active[slot]
            cap = min(st.request.max_new_tokens,
                      self.max_len - self.prompt_len)
            take = min(int(n_np[slot]), cap - len(st.produced))
            st.produced.extend(int(t) for t in toks_np[slot][:take])
            if bool(done_np[slot]) and take == int(n_np[slot]):
                self._retire(slot, "eos")
            elif len(st.produced) >= cap:
                self._retire(slot, "length")
        return bool(self._active or self._queue)

    def _retire(self, slot: int, reason: str) -> None:
        st = self._active.pop(slot)
        self._free.append(slot)
        self.stats["tokens_out"] += len(st.produced)
        self.completions.append(Completion(
            uid=st.request.uid, tokens=np.asarray(st.produced, np.int32),
            finish_reason=reason))

    def run(self, requests: Optional[List[Request]] = None) -> List[Completion]:
        """Drain the queue (plus ``requests``); returns all completions."""
        for r in requests or ():
            self.submit(r)
        t0 = time.perf_counter()
        while self.step():
            pass
        # stats are cumulative across run() calls (the engine is reusable)
        self.stats["wall_seconds"] += time.perf_counter() - t0
        self.stats["tokens_per_second"] = self.stats["tokens_out"] / max(
            self.stats["wall_seconds"], 1e-9)
        self.stats["dispatches_per_token"] = (
            self.stats["decode_dispatches"] / max(self.stats["tokens_out"], 1))
        return self.completions

    def compile_cache_size(self) -> Optional[int]:
        """Compiled-program count of the fused generate step (1 after warmup
        means no recompilation). None when the JAX version has no probe."""
        probe = getattr(self._generate, "_cache_size", None)
        return probe() if callable(probe) else None
