"""Serving driver: pre-sized cache prefill + fused on-device decode.

Two decode engines share one pre-sized cache layout (``model.init_cache``
sized to prompt_len + gen at prefill; no repad between phases):

  * ``loop``  — the per-token baseline: one jit dispatch + one host sync per
    generated token (what dispatch-bound PIM serving looks like).
  * ``fused`` — ``make_generate_step``: the whole decode loop runs inside one
    jit via ``jax.lax.scan`` (on-device sampling, cache donated/updated in
    place): 1 dispatch + 1 host sync per ``chunk`` tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch pimref-100m \
      --batch 4 --prompt-len 32 --gen 16 [--engine fused|loop] [--mode queue]
"""
from __future__ import annotations

import argparse
import os
import signal
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_IDS, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.distributed.chaos import ChaosConfig, ShardChaosConfig
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               RestartManifest)
from repro.launch import mesh as mesh_lib
from repro.launch.engine import Request, ServeEngine
from repro.launch.fleet import ServeFleet
from repro.launch.steps import (make_decode_step, make_serving_jits,
                                sample_tokens, spec_config)
from repro.models import build_model, init_params

# env knobs captured into (and replayed from) a serving RestartManifest so a
# restarted process traces the same cache layout / kernels / drafter
_SERVE_ENV_KNOBS = ("REPRO_KV_PAGES", "REPRO_KV_QUANT", "REPRO_SPEC_DECODE",
                    "REPRO_SPEC_K", "REPRO_ATTN_IMPL")


def _clone(tree):
    """Deep-copy a pytree, preserving each leaf's sharding (so a warmup call
    on the clone has the same jit signature as the real call)."""
    return jax.tree_util.tree_map(
        lambda x: jax.device_put(jnp.array(x), x.sharding), tree)


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          engine: str = "fused", chunk: int = 8, temperature: float = 0.0,
          top_k: int = 0, warmup: bool = True, spec: Optional[str] = None,
          spec_k: Optional[int] = None) -> Dict[str, Any]:
    """Prefill a synthetic batch then decode ``gen`` tokens per sequence.

    Returns tokens plus timing/dispatch metrics; with ``temperature == 0``
    both engines produce byte-identical greedy tokens — including with
    speculative decoding (``spec``/``spec_k``; default: the
    REPRO_SPEC_DECODE / REPRO_SPEC_K knobs), which additionally reports
    ``accepted_len_per_draft``.
    """
    assert engine in ("fused", "loop"), engine
    cfg = get_config(arch, smoke=smoke)
    max_len = prompt_len + gen
    shape = ShapeConfig("serve", seq_len=max_len, global_batch=batch,
                        mode="decode")
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, shape, mesh)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    with use_plan(plan):
        params = init_params(model.param_specs(), key)

    spec, spec_k = spec_config(model, spec, spec_k)
    if engine == "loop":
        spec = "off"                 # per-token baseline never speculates
    prefill, generate, rep, cache_sh = make_serving_jits(
        model, plan, max_len=max_len, chunk=chunk, temperature=temperature,
        top_k=top_k, spec=spec, spec_k=spec_k)
    decode = jax.jit(make_decode_step(model, plan), donate_argnums=(1,),
                     out_shardings=(None, cache_sh))
    n_chunks = -(-gen // chunk)

    rng = np.random.default_rng(seed)
    pre_batch: Dict[str, Any] = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        npatch = min(cfg.num_patches, prompt_len // 2)
        pre_batch["tokens"] = pre_batch["tokens"][:, : prompt_len - npatch]
        pre_batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, npatch, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        pre_batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, pre_batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jax.device_put(
        jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32), rep)
    gkey = jax.device_put(jax.random.PRNGKey(seed + 1), rep)

    if spec != "off":            # drafter history, seeded with the prompts
        tokf = np.asarray(pre_batch["tokens"])
        hcap = tokf.shape[1] + gen + chunk * (spec_k + 1)
        h0 = np.zeros((batch, hcap), np.int32)
        h0[:, :tokf.shape[1]] = tokf
        hist = jax.device_put(jnp.asarray(h0), rep)
        hist_len = jax.device_put(
            jnp.full((batch,), tokf.shape[1], jnp.int32), rep)

    eos = jnp.int32(-1)          # batch mode: length-only stopping
    if warmup:     # compile outside the timed region (clone: both jits donate)
        if engine == "loop":
            jax.block_until_ready(decode(params, _clone(cache), tok))
        elif spec != "off":
            jax.block_until_ready(
                generate(params, _clone(cache), tok, gkey, eos, _clone(hist),
                         _clone(hist_len))[5])
        else:
            jax.block_until_ready(
                generate(params, _clone(cache), tok, gkey, eos)[5])

    step_times: List[float] = []
    out_tokens: List[np.ndarray] = []
    dispatches = 0
    t0 = time.time()
    if engine == "loop":
        for _ in range(gen):
            ts = time.perf_counter()
            out_tokens.append(np.asarray(tok[:, 0]))    # host sync, every token
            logits, cache = decode(params, cache, tok)
            if temperature > 0:
                gkey, sub = jax.random.split(gkey)
                nxt = sample_tokens(logits[:, -1], sub, temperature, top_k)
            else:
                nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            tok = jax.device_put(nxt[:, None], rep)
            dispatches += 1
            step_times.append(time.perf_counter() - ts)
        jax.block_until_ready(tok)
        toks = np.stack(out_tokens, axis=1)
        per_tok = np.asarray(step_times)
    elif spec != "off":
        # draft-verify chunks commit a variable 1..spec_k+1 tokens per
        # iteration; drain the compacted buffers until every row has `gen`
        rows: List[List[int]] = [[] for _ in range(batch)]
        acc_sum = acc_iters = 0
        while min(len(r) for r in rows) < gen:
            ts = time.perf_counter()
            (cache, tok, gkey, _done, n_valid, toks_d, hist, hist_len, acc,
             _failed) = generate(params, cache, tok, gkey, eos, hist, hist_len)
            tb = np.asarray(toks_d)                     # host sync, per chunk
            nv = np.asarray(n_valid)
            live = np.asarray(acc)[np.asarray(acc) >= 0]
            acc_iters += int(live.size)
            acc_sum += int(live.sum())
            for r in range(batch):
                rows[r].extend(tb[r, : nv[r]].tolist())
            dispatches += 1
            step_times.append(time.perf_counter() - ts)
        toks = np.asarray([r[:gen] for r in rows], np.int32)
        per_tok = np.full(gen, sum(step_times) / gen)
    else:
        chunks: List[np.ndarray] = []
        for _ in range(n_chunks):
            ts = time.perf_counter()
            cache, tok, gkey, _done, _n, toks_d, _failed = generate(
                params, cache, tok, gkey, eos)
            chunks.append(np.asarray(toks_d))           # host sync, per chunk
            dispatches += 1
            step_times.append(time.perf_counter() - ts)
        toks = np.concatenate(chunks, axis=1)[:, :gen]
        per_tok = np.repeat(np.asarray(step_times) / chunk, chunk)[:gen]
    t_decode = time.time() - t0

    out = {
        "tokens": toks,
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(gen, 1),
        "throughput_tok_s": batch * gen / max(t_decode, 1e-9),
        "dispatches": dispatches,
        "dispatches_per_token": dispatches / max(gen, 1),
        "per_token_p50_s": float(np.percentile(per_tok, 50)),
        "per_token_p95_s": float(np.percentile(per_tok, 95)),
    }
    if spec != "off":
        out["accepted_len_per_draft"] = acc_sum / max(acc_iters, 1)
    return out


def make_queue_engine(arch: str, *, smoke: bool = True, slots: int = 4,
                      prompt_len: int = 32, gen: int = 16, chunk: int = 8,
                      seed: int = 0, temperature: float = 0.0, top_k: int = 0,
                      spec: Optional[str] = None, spec_k: Optional[int] = None,
                      **engine_kwargs: Any) -> ServeEngine:
    """Build a fresh :class:`ServeEngine` for ``arch`` (shared by queue mode,
    the chaos smokes, and checkpoint/restore). ``engine_kwargs`` forwards the
    robustness knobs (``max_queue``, ``deadline_ms``, ``chaos``,
    ``page_pool_pages``, ...)."""
    cfg = get_config(arch, smoke=smoke)
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(
        cfg, ShapeConfig("serve", prompt_len + gen, slots, "decode"), mesh)
    model = build_model(cfg)
    with use_plan(plan):
        params = init_params(model.param_specs(), jax.random.PRNGKey(seed))
    return ServeEngine(model, params, plan, slots=slots, prompt_len=prompt_len,
                       max_new=gen, chunk=chunk, temperature=temperature,
                       top_k=top_k, seed=seed, spec=spec, spec_k=spec_k,
                       **engine_kwargs)


def synth_requests(arch: str, *, smoke: bool = True, requests: int = 10,
                   prompt_len: int = 32, gen: int = 16, seed: int = 0,
                   shared_prefix: int = 0,
                   repeat_period: int = 0) -> List[Request]:
    """The synthetic mixed-length request stream used by queue mode — kept
    separate from the engine so a restore-verify run can rebuild the exact
    same queue the preempted process was draining."""
    cfg = get_config(arch, smoke=smoke)
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, cfg.vocab_size, shared_prefix).astype(np.int32)
    reqs = []
    for i in range(requests):
        n = int(rng.integers(max(4, shared_prefix + 1), prompt_len + 1))
        if repeat_period > 0:
            period = rng.integers(1, cfg.vocab_size,
                                  repeat_period).astype(np.int32)
            toks = np.tile(period, -(-n // repeat_period))[:n]
        else:
            toks = rng.integers(1, cfg.vocab_size, n).astype(np.int32)
        toks[:shared_prefix] = prefix
        reqs.append(Request(
            uid=i, tokens=toks,
            max_new_tokens=int(rng.integers(max(gen // 2, 1), gen + 1))))
    return reqs


def serve_queue(arch: str, *, smoke: bool = True, slots: int = 4,
                requests: int = 10, prompt_len: int = 32, gen: int = 16,
                chunk: int = 8, seed: int = 0, temperature: float = 0.0,
                top_k: int = 0, shared_prefix: int = 0,
                repeat_period: int = 0, spec: Optional[str] = None,
                spec_k: Optional[int] = None,
                max_queue: Optional[int] = None,
                deadline_ms: Optional[float] = None,
                chaos: Optional[ChaosConfig] = None,
                page_pool_pages: Optional[int] = None,
                stop: Optional[Callable[[ServeEngine], bool]] = None,
                ) -> ServeEngine:
    """Continuous batching: drain a queue of mixed-length synthetic requests
    through a :class:`ServeEngine`; returns the drained engine (stats +
    completions). ``shared_prefix > 0`` gives every request the same first
    tokens (a common system prompt) — with the paged cache, concurrent slots
    then hash-cons their full prefix pages instead of duplicating them.
    ``repeat_period > 0`` tiles each prompt from a short per-request period
    (the lookup-friendly repetitive-suffix workload for the n-gram drafter);
    ``spec``/``spec_k`` select the speculative-decoding drafter (default:
    the env knobs). Robustness knobs: ``max_queue`` bounds the admission
    queue, ``deadline_ms`` retires overdue requests, ``chaos`` arms seeded
    fault injection, ``page_pool_pages`` shrinks the paged-cache pool, and
    ``stop(engine)`` halts the drain early (preemption)."""
    eng = make_queue_engine(
        arch, smoke=smoke, slots=slots, prompt_len=prompt_len, gen=gen,
        chunk=chunk, seed=seed, temperature=temperature, top_k=top_k,
        spec=spec, spec_k=spec_k, max_queue=max_queue, deadline_ms=deadline_ms,
        chaos=chaos, page_pool_pages=page_pool_pages)
    reqs = synth_requests(arch, smoke=smoke, requests=requests,
                          prompt_len=prompt_len, gen=gen, seed=seed,
                          shared_prefix=shared_prefix,
                          repeat_period=repeat_period)
    eng.run(reqs, stop=(lambda: stop(eng)) if stop is not None else None)
    return eng


def make_fleet(arch: str, *, shards: int = 2, backend: str = "inproc",
               smoke: bool = True, slots: int = 4, prompt_len: int = 32,
               gen: int = 16, chunk: int = 8, seed: int = 0,
               temperature: float = 0.0, top_k: int = 0,
               spec: Optional[str] = None, spec_k: Optional[int] = None,
               fleet_chaos: Optional[ShardChaosConfig] = None,
               checkpoint_every: int = 1, manifest_dir: Optional[str] = None,
               miss_suspect: int = 2, miss_dead: int = 4,
               heartbeat_timeout_s: float = 120.0, max_replays: int = 2,
               **engine_kwargs: Any) -> ServeFleet:
    """Build a :class:`ServeFleet` of identical engine shards.

    Every shard gets the same arch/seed/knobs, so any shard decodes any
    request byte-identically — the property failover replay rests on. The
    ``mp`` backend additionally records the serving env knobs so spawned
    workers trace the same cache layout / kernels / drafter."""
    ekw = dict(arch=arch, smoke=smoke, slots=slots, prompt_len=prompt_len,
               gen=gen, chunk=chunk, seed=seed, temperature=temperature,
               top_k=top_k, spec=spec, spec_k=spec_k, **engine_kwargs)
    factory = worker_spec = None
    if backend == "mp":
        worker_spec = {"engine": ekw,
                       "env": {k: os.environ[k] for k in _SERVE_ENV_KNOBS
                               if k in os.environ}}
    else:
        factory = lambda sid: make_queue_engine(**ekw)  # noqa: E731
    return ServeFleet(factory, shards=shards, backend=backend,
                      worker_spec=worker_spec, chaos=fleet_chaos,
                      checkpoint_every=checkpoint_every,
                      manifest_dir=manifest_dir, miss_suspect=miss_suspect,
                      miss_dead=miss_dead,
                      heartbeat_timeout_s=heartbeat_timeout_s,
                      max_replays=max_replays, seed=seed)


def serve_fleet(arch: str, *, smoke: bool = True, shards: int = 2,
                backend: str = "inproc", slots: int = 4, requests: int = 10,
                prompt_len: int = 32, gen: int = 16, chunk: int = 8,
                seed: int = 0, temperature: float = 0.0, top_k: int = 0,
                shared_prefix: int = 0, repeat_period: int = 0,
                spec: Optional[str] = None, spec_k: Optional[int] = None,
                **fleet_kwargs: Any) -> ServeFleet:
    """Drain the queue-mode synthetic request stream through a sharded
    fleet; returns the drained fleet (caller closes it). The request stream
    is identical to :func:`serve_queue`'s, so a 1-shard reference engine
    drains the exact same queue for byte-identity verification."""
    fleet = make_fleet(arch, shards=shards, backend=backend, smoke=smoke,
                       slots=slots, prompt_len=prompt_len, gen=gen,
                       chunk=chunk, seed=seed, temperature=temperature,
                       top_k=top_k, spec=spec, spec_k=spec_k, **fleet_kwargs)
    reqs = synth_requests(arch, smoke=smoke, requests=requests,
                          prompt_len=prompt_len, gen=gen, seed=seed,
                          shared_prefix=shared_prefix,
                          repeat_period=repeat_period)
    fleet.run(reqs)
    return fleet


def _print_fleet_stats(fleet: ServeFleet) -> None:
    s = fleet.stats
    print(f"fleet: {fleet.n_shards} shards ({fleet.backend}), "
          f"{len(fleet.completions)} requests, {s['tokens_out']} tokens in "
          f"{s['wall_seconds']:.2f}s ({s['tokens_per_second']:.1f} tok/s), "
          f"{s['fleet_steps']} fleet steps, {s['checkpoints']} checkpoints")
    if (s["failovers"] or s["heartbeat_misses"] or s["error_completions"]
            or fleet.chaos_events):
        print(f"fleet robust: {s['failovers']} failovers "
              f"({s['replays']} replays, {s['shard_lost']} shard_lost), "
              f"{s['heartbeat_misses']} heartbeat misses "
              f"({s.get('suspects', 0)} suspects, "
              f"{s.get('recoveries', 0)} recoveries, "
              f"{s.get('deaths', 0)} deaths), "
              f"{s['error_completions']} error completions, "
              f"{len(fleet.chaos_events)} chaos events")
    for row in fleet.per_shard_stats():
        print(f"  shard {row['shard']} [{row['state']}]: "
              f"{row['tokens_out']} tokens, {row['dispatches']} dispatches, "
              f"{row['tok_s']:.1f} tok/s, p50 {row['p50_ms']:.1f}ms, "
              f"p95 {row['p95_ms']:.1f}ms")


def save_serve_manifest(path: str, eng: ServeEngine, *, arch: str,
                        smoke: bool, slots: int, prompt_len: int, gen: int,
                        chunk: int,
                        queue: Optional[Dict[str, Any]] = None) -> None:
    """Write a serving :class:`RestartManifest`: the engine snapshot plus the
    engine/env config a restarted process needs to rebuild identical jits."""
    snap = eng.snapshot()
    snap["engine"] = {
        "arch": arch, "smoke": smoke, "slots": slots,
        "prompt_len": prompt_len, "gen": gen, "chunk": chunk,
        "top_k": eng._top_k, "spec": eng.spec, "spec_k": eng.spec_k,
        "env": {k: os.environ[k] for k in _SERVE_ENV_KNOBS
                if k in os.environ},
    }
    if queue is not None:
        snap["engine"]["queue"] = queue
    RestartManifest(
        step=eng.stats["decode_dispatches"], checkpoint_dir="",
        mesh_shape=[jax.device_count()], mesh_axes=["data"],
        data_seed=eng.seed, arch=arch, shape="serve",
        straggler_events=list(eng._straggler.flagged), serve=snap,
    ).save(path)


def restore_serve(path: str) -> ServeEngine:
    """Rebuild a :class:`ServeEngine` from a serving manifest and drain it.

    Env knobs recorded at snapshot time are replayed before tracing so the
    restored process uses the same cache layout / kernels / drafter. With the
    paged cache, in-flight requests resume from ``prompt + produced`` (page
    positions are bucket-independent); the contiguous layout regenerates from
    the original prompt. Both drain to byte-identical greedy completions.
    """
    man = RestartManifest.load(path)
    assert man.serve is not None, f"{path}: not a serving manifest"
    snap = man.serve
    ecfg = snap["engine"]
    for k, v in ecfg.get("env", {}).items():
        os.environ[k] = v
    prompt_len = ecfg["prompt_len"]
    if os.environ.get("REPRO_KV_PAGES", "0") not in ("", "0"):
        # paged resume re-prefills prompt + produced; the prompt bucket must
        # fit the longest such prefix (positions are true, so growing the
        # bucket cannot change surviving tokens)
        need = max((len(d["tokens"]) + len(d.get("produced", []))
                    for d in snap.get("queued", []) + snap.get("active", [])),
                   default=0)
        prompt_len = max(prompt_len, need)
    eng = make_queue_engine(
        ecfg["arch"], smoke=ecfg["smoke"], slots=ecfg["slots"],
        prompt_len=prompt_len, gen=ecfg["gen"], chunk=ecfg["chunk"],
        seed=snap["seed"], temperature=snap["temperature"],
        top_k=ecfg.get("top_k", 0), spec=ecfg.get("spec"),
        spec_k=ecfg.get("spec_k"))
    eng.load_snapshot(snap)
    eng.run()
    return eng


def _print_queue_stats(eng: ServeEngine) -> None:
    s = eng.stats
    print(f"{len(eng.completions)} requests, {s['tokens_out']} tokens in "
          f"{s['wall_seconds']:.2f}s ({s['tokens_per_second']:.1f} tok/s, "
          f"{s['dispatches_per_token']:.3f} dispatches/token, "
          f"{s['prefills']} prefills)")
    print(f"kv: {s['kv_hbm_bytes_peak'] / 1e6:.2f} MB peak "
          f"({s['kv_bytes_per_token']:.0f} B/token"
          + (f", {s['kv_pages_peak']} pages peak, "
             f"{s['prefix_hits']} prefix hits" if eng.paged else "")
          + ")")
    if eng.spec != "off":
        print(f"spec: mode={eng.spec} k={eng.spec_k} accepted_len/draft="
              f"{s['spec_accepted_len_per_draft']:.3f} "
              f"accept hist={s['spec_accept_hist']}")
    robust = (s["error_completions"] or s["deadline_miss"] or s["retries"]
              or s["shed_events"] or s["admission_blocked"]
              or eng.chaos_events)
    if robust:
        print(f"robust: {s['error_completions']} error completions "
              f"({s['deadline_miss']} deadline misses), "
              f"{s['retries']} retries, {s['shed_events']} shed events, "
              f"{s['straggler_events']} stragglers, "
              f"{s['admission_blocked']} admission stalls, "
              f"queue peak {s['queue_peak']}, "
              f"{len(eng.chaos_events)} chaos events")


def _assert_identical(eng: ServeEngine, ref: ServeEngine, label: str,
                      skip_uids=()) -> int:
    """Assert ``eng``'s non-error completions match ``ref`` byte-for-byte
    (minus ``skip_uids``); returns how many were compared."""
    got = {c.uid: c for c in eng.completions}
    want = sorted(c.uid for c in ref.completions)
    assert sorted(got) == want, (
        f"{label}: completion uids {sorted(got)} != {want}")
    checked = 0
    for c in ref.completions:
        g = got[c.uid]
        if g.finish_reason == "error" or c.uid in skip_uids:
            continue
        assert list(np.asarray(g.tokens)) == list(np.asarray(c.tokens)), (
            f"{label} mismatch on uid={c.uid}: "
            f"{np.asarray(g.tokens)} != {np.asarray(c.tokens)}")
        checked += 1
    return checked


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--engine", default="fused", choices=["fused", "loop"])
    ap.add_argument("--mode", default="batch", choices=["batch", "queue"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "pallas", "jnp"],
                    help="attention backend for every model family "
                    "(sets REPRO_ATTN_IMPL before programs are traced)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["off", "int8", "int4", "auto"],
                    help="Proteus-quantized KV cache for the decode hot path "
                    "(sets REPRO_KV_QUANT before programs are traced)")
    ap.add_argument("--kv-page-size", type=int, default=None,
                    help="paged KV cache with this many tokens per page "
                    "(sets REPRO_KV_PAGES before programs are traced; "
                    "0 = contiguous per-slot cache)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="queue mode: give every request the same first N "
                    "tokens (exercises paged prefix sharing)")
    ap.add_argument("--spec-decode", default=None,
                    choices=["off", "ngram", "draft"],
                    help="speculative decoding drafter inside the fused scan "
                    "(sets REPRO_SPEC_DECODE before programs are traced)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="draft length per speculative iteration "
                    "(sets REPRO_SPEC_K)")
    ap.add_argument("--repeat-period", type=int, default=0,
                    help="queue mode: tile each prompt from a short period "
                    "(lookup-friendly workload for the n-gram drafter)")
    ap.add_argument("--spec-verify", action="store_true",
                    help="queue mode: re-drain the identical queue with "
                    "speculation forced off and assert byte-identical "
                    "completions (greedy identity gate)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="queue mode: bound the admission queue; submissions "
                    "beyond it get a queue_full error Completion")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="queue mode: per-request deadline; overdue requests "
                    "retire with a deadline error Completion")
    ap.add_argument("--page-pool-pages", type=int, default=None,
                    help="queue mode: physical page budget for the paged "
                    "cache pool (default slots * pages-per-slot)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="queue mode: arm seeded fault injection; plan comes "
                    "from REPRO_CHAOS (e.g. 'nan=1,slow=2,fail=1,pages=4') "
                    "or defaults to nan=1,slow=1,fail=1")
    ap.add_argument("--chaos-verify", action="store_true",
                    help="queue mode: re-drain the identical queue without "
                    "chaos and assert fault-free survivors are "
                    "byte-identical")
    ap.add_argument("--preempt-after", type=int, default=None,
                    help="queue mode: raise SIGTERM after N chunk dispatches "
                    "and checkpoint in-flight state to --snapshot "
                    "(exercises the real signal path)")
    ap.add_argument("--snapshot", default="serve_manifest.json",
                    help="manifest path written on preemption (SIGTERM or "
                    "--preempt-after)")
    ap.add_argument("--restore", default=None,
                    help="restore a serving manifest and drain the remaining "
                    "requests (implies queue mode)")
    ap.add_argument("--restore-verify", action="store_true",
                    help="with --restore: also run the original queue "
                    "uninterrupted and assert byte-identical completions")
    ap.add_argument("--shards", type=int, default=1,
                    help="queue mode: drain through a ServeFleet of this "
                    "many engine shards behind one dispatcher (1 = single "
                    "engine, no fleet)")
    ap.add_argument("--fleet-backend", default="inproc",
                    choices=["inproc", "mp"],
                    help="shard placement: in-process objects or "
                    "multiprocessing workers (the CPU multi-host stand-in)")
    ap.add_argument("--fleet-chaos", default=None,
                    help="shard-level fault plan, e.g. 'kill=1@2' (kill "
                    "shard 1 at fleet step 2), 'stall=0@3', 'drop=1@2x2', "
                    "or seeded budgets 'kills=1,seed=7' (implies --shards)")
    ap.add_argument("--fleet-verify", action="store_true",
                    help="re-drain the identical queue through one engine "
                    "and assert exactly one completion per request with "
                    "byte-identical survivor outputs")
    ap.add_argument("--heartbeat-timeout", type=float, default=120.0,
                    help="seconds a shard may owe its step reply before the "
                    "fleet counts a missed heartbeat")
    ap.add_argument("--miss-suspect", type=int, default=2,
                    help="consecutive missed heartbeats before a shard is "
                    "SUSPECT (no new routing)")
    ap.add_argument("--miss-dead", type=int, default=4,
                    help="consecutive missed heartbeats before a shard is "
                    "DEAD (failover)")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="fleet steps between periodic shard snapshots "
                    "(the failover replay source)")
    ap.add_argument("--manifest-dir", default=None,
                    help="persist each shard snapshot as an atomic "
                    "RestartManifest under this directory")
    ap.add_argument("--full", dest="smoke", action="store_false", default=True)
    args = ap.parse_args()
    if args.attn_impl:
        os.environ["REPRO_ATTN_IMPL"] = args.attn_impl
    if args.kv_quant:
        os.environ["REPRO_KV_QUANT"] = args.kv_quant
    if args.kv_page_size is not None:
        os.environ["REPRO_KV_PAGES"] = str(args.kv_page_size)
    if args.spec_decode:
        os.environ["REPRO_SPEC_DECODE"] = args.spec_decode
    if args.spec_k is not None:
        os.environ["REPRO_SPEC_K"] = str(args.spec_k)
    if args.restore:
        eng = restore_serve(args.restore)
        _print_queue_stats(eng)
        if args.restore_verify:
            man = RestartManifest.load(args.restore)
            e, q = man.serve["engine"], man.serve["engine"].get("queue")
            assert q, "--restore-verify needs a manifest saved by queue mode"
            ref = serve_queue(
                e["arch"], smoke=e["smoke"], slots=e["slots"],
                requests=q["requests"], prompt_len=e["prompt_len"],
                gen=e["gen"], chunk=e["chunk"], seed=man.serve["seed"],
                temperature=man.serve["temperature"], top_k=e.get("top_k", 0),
                shared_prefix=q.get("shared_prefix", 0),
                repeat_period=q.get("repeat_period", 0))
            n = _assert_identical(eng, ref, "restore-verify")
            print(f"restore-verify: {n} completions byte-identical with an "
                  "uninterrupted drain")
        return
    if args.mode == "queue":
        chaos = ChaosConfig.from_env(args.chaos_seed)
        if chaos is None and args.chaos_seed is not None:
            chaos = ChaosConfig.parse("nan=1,slow=1,fail=1",
                                      seed=args.chaos_seed)
        handler = stop = None
        if args.preempt_after is not None:
            handler = PreemptionHandler().install()

            def stop(e, _h=handler):
                if (not _h.requested and
                        e.stats["decode_dispatches"] >= args.preempt_after):
                    os.kill(os.getpid(), signal.SIGTERM)
                return _h.requested

        queue_kw = dict(
            smoke=args.smoke, slots=args.slots, requests=args.requests,
            prompt_len=args.prompt_len, gen=args.gen, chunk=args.chunk,
            temperature=args.temperature, top_k=args.top_k,
            shared_prefix=args.shared_prefix,
            repeat_period=args.repeat_period)
        if args.shards > 1 or args.fleet_chaos or \
                args.fleet_backend == "mp":
            fc = (ShardChaosConfig.parse(args.fleet_chaos,
                                         seed=args.chaos_seed or 0)
                  if args.fleet_chaos else None)
            fleet = serve_fleet(
                args.arch, shards=max(args.shards, 1),
                backend=args.fleet_backend, fleet_chaos=fc,
                checkpoint_every=args.checkpoint_every,
                manifest_dir=args.manifest_dir,
                miss_suspect=args.miss_suspect, miss_dead=args.miss_dead,
                heartbeat_timeout_s=args.heartbeat_timeout, **queue_kw)
            _print_fleet_stats(fleet)
            try:
                if args.fleet_verify:
                    uids = sorted(c.uid for c in fleet.completions)
                    assert uids == list(range(args.requests)), (
                        f"fleet-verify: expected exactly one completion per "
                        f"request, got uids {uids}")
                    ref = serve_queue(args.arch, **queue_kw)
                    n = _assert_identical(fleet, ref, "fleet-verify")
                    print(f"fleet-verify: {n}/{args.requests} surviving "
                          f"completions byte-identical with a single-engine "
                          f"drain ({fleet.stats['failovers']} failovers)")
            finally:
                fleet.close()
            return
        eng = serve_queue(args.arch, max_queue=args.max_queue,
                          deadline_ms=args.deadline_ms, chaos=chaos,
                          page_pool_pages=args.page_pool_pages, stop=stop,
                          **queue_kw)
        _print_queue_stats(eng)
        if handler is not None:
            handler.uninstall()
            if handler.requested:
                save_serve_manifest(
                    args.snapshot, eng, arch=args.arch, smoke=args.smoke,
                    slots=args.slots, prompt_len=args.prompt_len,
                    gen=args.gen, chunk=args.chunk,
                    queue={"requests": args.requests,
                           "shared_prefix": args.shared_prefix,
                           "repeat_period": args.repeat_period})
                print(f"preempted after {eng.stats['decode_dispatches']} "
                      f"chunks: {len(eng.completions)}/{args.requests} done, "
                      f"manifest -> {args.snapshot}")
                return
        if args.chaos_verify and chaos is not None:
            ref = serve_queue(args.arch, **queue_kw)
            poisoned = {ev["uid"] for ev in eng.chaos_events
                        if ev["kind"] == "nan"}
            n = _assert_identical(eng, ref, "chaos-verify",
                                  skip_uids=poisoned)
            print(f"chaos-verify: {n}/{len(eng.completions)} fault-free "
                  f"survivors byte-identical "
                  f"({len(eng.chaos_events)} injected events)")
        if args.spec_verify and eng.spec != "off":
            ref = serve_queue(args.arch, spec="off", **queue_kw)
            n = _assert_identical(eng, ref, "spec-verify")
            print(f"spec-verify: {n} completions byte-identical with "
                  "speculation off")
        return
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen, chunk=args.chunk,
                engine=args.engine, temperature=args.temperature,
                top_k=args.top_k)
    print(f"engine={args.engine}  prefill: {out['prefill_s']:.3f}s  decode: "
          f"{out['decode_s_per_tok'] * 1e3:.1f}ms/tok  "
          f"throughput: {out['throughput_tok_s']:.1f} tok/s  "
          f"dispatches/token: {out['dispatches_per_token']:.3f}")
    if "accepted_len_per_draft" in out:
        print(f"spec accepted_len/draft: {out['accepted_len_per_draft']:.3f}")
    print("sample tokens:", out["tokens"][0][:10])


if __name__ == "__main__":
    main()
