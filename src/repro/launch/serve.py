"""Serving driver: batched prefill + decode with a continuous request queue.

  PYTHONPATH=src python -m repro.launch.serve --arch pimref-100m \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_IDS, RunConfig, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import build_model, init_params


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 16, seed: int = 0,
          greedy: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch, smoke=smoke)
    shape = ShapeConfig("serve", seq_len=prompt_len + gen, global_batch=batch,
                        mode="decode")
    mesh = mesh_lib.make_local_mesh(("data",))
    plan = plan_sharding(cfg, shape, mesh)
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    with use_plan(plan):
        params = init_params(model.param_specs(), key)

    prefill = jax.jit(make_prefill_step(model, plan))
    decode = jax.jit(make_decode_step(model, plan), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    pre_batch: Dict[str, Any] = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)}
    if cfg.family == "vlm":
        P = min(cfg.num_patches, prompt_len // 2)
        pre_batch["tokens"] = pre_batch["tokens"][:, : prompt_len - P]
        pre_batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((batch, P, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        pre_batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, prompt_len, cfg.d_model)), jnp.float32)

    t0 = time.time()
    logits, cache = prefill(params, pre_batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    # grow caches that were sized by prefill (full-attn caches sized to prompt)
    cache = _grow_cache(model, cache, batch, prompt_len + gen)

    out_tokens: List[np.ndarray] = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    toks = np.stack(out_tokens, axis=1)
    return {
        "tokens": toks,
        "prefill_s": t_prefill,
        "decode_s_per_tok": t_decode / max(gen, 1),
        "throughput_tok_s": batch * gen / max(t_decode, 1e-9),
    }


def _grow_cache(model, cache, batch: int, max_len: int):
    """Re-host prefill caches inside a max_len-sized decode cache."""
    template = model.init_cache(batch, max_len)

    def place(t, c):
        if not hasattr(t, "shape") or t.shape == getattr(c, "shape", None):
            return c
        if t.ndim == c.ndim and t.shape != c.shape:
            # pad sequence dims up to template size (-1 for position ids)
            pads = [(0, ts - cs) for ts, cs in zip(t.shape, c.shape)]
            if all(p[1] >= 0 for p in pads):
                fill = -1 if (c.dtype == jnp.int32 and c.ndim == 1) else 0
                return jnp.pad(c, pads, constant_values=fill)
        return c

    return jax.tree_util.tree_map(place, template, cache)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", dest="smoke", action="store_false", default=True)
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print(f"prefill: {out['prefill_s']:.3f}s  decode: "
          f"{out['decode_s_per_tok'] * 1e3:.1f}ms/tok  "
          f"throughput: {out['throughput_tok_s']:.1f} tok/s")
    print("sample tokens:", out["tokens"][0][:10])


if __name__ == "__main__":
    main()
