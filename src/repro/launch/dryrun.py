import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first lines: jax locks device count on first init. The 512
#   placeholder host devices exist ONLY in this process (dry-run); smoke
#   tests and benches see the real 1-device platform.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * the MIMDRAM planner resolves the data mapping,
  * the step function (train_step or serve_step) is jit'd with explicit
    in_shardings and lowered against ShapeDtypeStruct stand-ins,
  * ``compiled.memory_analysis()`` proves per-device fit,
  * ``compiled.cost_analysis()`` + the DAMOV HLO analyzer (trip-count-aware)
    produce the roofline terms recorded in EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch stablelm-3b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/results
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k \
      --proteus --tag proteus_int8
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import (ARCH_IDS, RunConfig, SHAPES_BY_NAME, get_config)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import damov
from repro.core.mimdram import plan_sharding
from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models import module as mod

HBM_PER_CHIP = 16 * 1024 ** 3  # v5e-class


def active_param_count_from_specs(model, cfg: ModelConfig) -> int:
    total = mod.count_params(model.param_specs())
    if cfg.num_experts and cfg.experts_per_token:
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = cfg.num_layers * (cfg.num_experts - cfg.experts_per_token) \
            * per_expert
        return total - inactive
    return total


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("full-attention arch: 524k dense KV cache at batch 1 is "
                "architecturally meaningless (assignment rule); runs only for "
                "SSM/hybrid/sliding-window archs")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool, run: RunConfig,
             overrides: Dict[str, Any], tag: str, out_dir: str,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if shape.mode in ("prefill", "decode"):
        # serving runs bf16 weights (standard practice; int8 via Proteus is
        # the beyond-paper step recorded separately in §Perf)
        cfg = cfg.replace(param_dtype="bfloat16")
    if overrides:
        cfg = cfg.replace(**overrides)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mdesc = mesh_lib.describe(mesh)
    chips = mesh_lib.n_chips(mesh)
    row: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mdesc, "tag": tag,
        "multi_pod": multi_pod, "chips": chips, "status": "pending",
    }

    reason = skip_reason(cfg, shape)
    if reason:
        row.update(status="SKIP", reason=reason)
        _save(row, out_dir)
        return row

    t0 = time.time()
    try:
        plan = plan_sharding(cfg, shape, mesh)
        (model, step, args, shardings, donate, eff_run,
         out_sh) = steps_lib.cell_artifacts(cfg, shape, plan, run)
        row["microbatches"] = eff_run.microbatches
        jitted = jax.jit(step, in_shardings=shardings, out_shardings=out_sh,
                         donate_argnums=donate or None)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        stats = damov.analyze_hlo(compiled.as_text())
        n_active = active_param_count_from_specs(model, cfg)
        mf = damov.model_flops_for(cfg, shape, n_active)
        roof = damov.make_roofline(arch, shape_name, shape.mode, mdesc, chips,
                                   stats, mf, notes="; ".join(plan.notes))

        arg_b = getattr(mem, "argument_size_in_bytes", 0)
        tmp_b = getattr(mem, "temp_size_in_bytes", 0)
        out_b = getattr(mem, "output_size_in_bytes", 0)
        peak = arg_b + tmp_b
        # steady-state bound: args + outputs (donation aliases in/out on TPU;
        # CPU-XLA scan bodies copy caches in/out, inflating temp_bytes)
        steady = arg_b + out_b
        row.update(
            status="OK",
            seconds_lower=round(t_lower, 1),
            seconds_compile=round(t_compile, 1),
            memory={"argument_bytes": int(arg_b), "temp_bytes": int(tmp_b),
                    "output_bytes": int(out_b), "peak_bytes": int(peak),
                    "fits_16GB": bool(peak <= HBM_PER_CHIP),
                    "peak_GB": round(peak / 2 ** 30, 2),
                    "steady_GB": round(steady / 2 ** 30, 2),
                    "steady_fits_16GB": bool(steady <= HBM_PER_CHIP)},
            xla_cost={"flops": cost.get("flops", 0.0),
                      "bytes_accessed": cost.get("bytes accessed", 0.0)},
            damov=dataclasses.asdict(roof),
            plan={"notes": list(plan.notes),
                  "segment_utilization": plan.segment_utilization,
                  "segments": plan.segments,
                  "rules": {k: list(v) if v else None
                            for k, v in plan.rules.items()}},
            params_total=mod.count_params(model.param_specs()),
            params_active=n_active,
        )
        if verbose:
            print(f"[{arch} x {shape_name} x {mdesc}{' #'+tag if tag else ''}] "
                  f"OK peak={row['memory']['peak_GB']}GB "
                  f"dominant={roof.dominant} class={roof.bottleneck_class} "
                  f"rf={roof.roofline_fraction:.3f} "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
            print(f"  terms: compute={roof.compute_s:.3e}s "
                  f"memory={roof.memory_s:.3e}s coll={roof.collective_s:.3e}s "
                  f"MF/HF={roof.useful_ratio:.3f}")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        row.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{arch} x {shape_name} x {mdesc}] FAIL: {e}")
    _save(row, out_dir)
    return row


def _save(row: Dict[str, Any], out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    tag = ("_" + row["tag"]) if row.get("tag") else ""
    name = f"{row['arch']}_{row['shape']}_{row['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name.replace("=", "")), "w") as f:
        json.dump(row, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES_BY_NAME) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--tag", default="")
    ap.add_argument("--proteus", action="store_true",
                    help="quantized cross-pod gradient reduction (multi-pod)")
    ap.add_argument("--proteus-bits", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--set", action="append", default=[],
                    help="ModelConfig overrides k=v (e.g. attn_block_skip=1)")
    args = ap.parse_args()

    run = RunConfig(proteus_enabled=args.proteus,
                    proteus_grad_bits=args.proteus_bits,
                    microbatches=args.microbatches)
    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        cur = getattr(ModelConfig, k, None)
        overrides[k] = type(cur)(eval(v)) if cur is not None else eval(v)

    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES_BY_NAME) if (args.all or not args.shape) \
        else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_cell(arch, shape, mp, run, overrides,
                                        args.tag, args.out))
    ok = sum(r["status"] == "OK" for r in results)
    sk = sum(r["status"] == "SKIP" for r in results)
    fa = sum(r["status"] == "FAIL" for r in results)
    print(f"\ndry-run: {ok} OK, {sk} SKIP, {fa} FAIL / {len(results)} cells")
    if fa:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
