"""Training driver: end-to-end loop with checkpointing + fault tolerance.

Runs real training on whatever devices exist (CPU smoke configs, TPU slices)
using the same planner/step machinery the dry-run proves out at 512 chips.

  PYTHONPATH=src python -m repro.launch.train --arch pimref-100m --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 50 --checkpoint-dir /tmp/ck --resume
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import CheckpointManager
from repro.configs import (ALL_IDS, RunConfig, SHAPES_BY_NAME, ShapeConfig,
                           get_config)
from repro.core.mimdram import plan_sharding, use_plan
from repro.data import make_batch_fn
from repro.distributed import (PreemptionHandler, RestartManifest,
                               StragglerMonitor)
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step
from repro.models import build_model, init_params
from repro.models import module as mod
from repro.optim import make_optimizer


def train(arch: str, *, smoke: bool = True, steps: int = 100,
          batch: int = 8, seq: int = 128, run: Optional[RunConfig] = None,
          checkpoint_dir: str = "", resume: bool = False,
          log_every: int = 10, use_mesh: bool = True,
          proteus: bool = False) -> Dict[str, Any]:
    print(compat.describe_support())
    cfg = get_config(arch, smoke=smoke)
    run = run or RunConfig(total_steps=steps, microbatches=1)
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch, mode="train")

    mesh = mesh_lib.make_local_mesh(("data",)) if use_mesh else None
    plan = plan_sharding(cfg, shape, mesh)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer, run)

    key = jax.random.PRNGKey(run.seed)
    with use_plan(plan):
        params = init_params(model.param_specs(), key)
        opt_state = optimizer.init(params)

    step_fn = jax.jit(make_train_step(model, optimizer, plan, run),
                      donate_argnums=(0, 1))
    batch_fn = make_batch_fn(cfg, shape, seed=run.seed)

    start = 0
    ckpt = CheckpointManager(checkpoint_dir, keep=run.keep_checkpoints) \
        if checkpoint_dir else None
    if ckpt and resume and ckpt.latest_step() is not None:
        start, state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        print(f"resumed from step {start}")

    preempt = PreemptionHandler().install()
    straggler = StragglerMonitor()
    losses = []
    t_begin = time.time()
    for step in range(start, steps):
        straggler.step_start()
        b = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        flag = straggler.step_end(step)
        if flag:
            print(f"  straggler flag: {flag}")
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t_begin
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"({dt / max(step - start + 1, 1):.2f}s/step)")
        if ckpt and ((step + 1) % run.checkpoint_every == 0
                     or preempt.requested or step == steps - 1):
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      extra={"loss": loss})
            RestartManifest(
                step=step + 1, checkpoint_dir=checkpoint_dir,
                mesh_shape=list(mesh.shape.values()) if mesh else [1],
                mesh_axes=list(mesh.shape.keys()) if mesh else ["data"],
                data_seed=run.seed, arch=arch, shape=shape.name,
                straggler_events=straggler.flagged,
            ).save(os.path.join(checkpoint_dir, "manifest.json"))
            if preempt.requested:
                print(f"preemption requested: checkpointed at {step + 1}, "
                      "exiting cleanly")
                break
    preempt.uninstall()
    if ckpt:
        ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "params": params, "opt_state": opt_state}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "pallas", "jnp"],
                    help="attention backend (sets REPRO_ATTN_IMPL before "
                    "the train step is traced)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["off", "int8", "int4", "auto"],
                    help="Proteus-quantized KV cache for any eval/serve "
                    "prefill+decode launched from this process (sets "
                    "REPRO_KV_QUANT; the train step itself has no KV cache)")
    args = ap.parse_args()
    if args.attn_impl:
        os.environ["REPRO_ATTN_IMPL"] = args.attn_impl
    if args.kv_quant:
        os.environ["REPRO_KV_QUANT"] = args.kv_quant
    run = RunConfig(total_steps=args.steps, learning_rate=args.lr,
                    microbatches=1)
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, run=run,
                checkpoint_dir=args.checkpoint_dir, resume=args.resume)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
