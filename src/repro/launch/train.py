"""Training driver: guarded end-to-end loop with verified checkpoints,
bitwise-identical resume, chaos injection, and a bounded-restart supervisor.

Runs real training on whatever devices exist (CPU smoke configs, TPU slices)
using the same planner/step machinery the dry-run proves out at 512 chips.
The loop mirrors the serving stack's failure model (PR 8/9) on the training
side:

* every jitted step carries an on-device non-finite guard — a NaN/Inf loss
  or gradient skips the optimizer update (params pass through unchanged,
  donation preserved) and ``max_bad_steps`` consecutive skips abort with a
  typed :class:`TrainDivergedError`;
* a host-side loss-spike detector (EWMA + factor threshold) rolls back to
  the last good checkpoint and re-seeds the data window (``salt``), so a
  poisonous batch window is not replayed verbatim;
* checkpoints capture the full loop state (RNG key, data cursor/salt,
  skip/rollback counters, loss EWMA), so an interrupted+resumed run's losses
  and final params are *byte-identical* to an uninterrupted run — gated by
  :func:`verify_resume_identity`;
* :class:`TrainSupervisor` wraps :func:`train` in a bounded auto-restart
  loop resuming from the last *verified* checkpoint (restore walks back past
  torn/corrupt checkpoints).

  PYTHONPATH=src python -m repro.launch.train --arch pimref-100m --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --smoke \
      --steps 50 --checkpoint-dir /tmp/ck --resume
  PYTHONPATH=src python -m repro.launch.train --arch pimref-100m --steps 12 \
      --chaos-seed 7                      # REPRO_CHAOS="nan=2,slow=1" ...
  PYTHONPATH=src python -m repro.launch.train --arch pimref-100m --steps 10 \
      --checkpoint-dir /tmp/ck --checkpoint-every 3 --preempt-after 5 \
      --max-restarts 2 --resume-verify    # byte-identity gate
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time
import warnings
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.checkpoint import (CheckpointManager, CheckpointWriteError)
from repro.configs import ALL_IDS, RunConfig, ShapeConfig, get_config
from repro.core.mimdram import plan_sharding, use_plan
from repro.data import make_batch_fn
from repro.distributed import (PreemptionHandler, RestartManifest,
                               StragglerMonitor, TrainChaosConfig,
                               TrainChaosMonkey)
from repro.distributed.chaos import nan_grad_hook
from repro.launch import mesh as mesh_lib
from repro.launch.steps import make_train_step
from repro.models import build_model, init_params
from repro.optim import make_optimizer


class TrainDivergedError(RuntimeError):
    """``max_bad_steps`` consecutive steps were skipped by the non-finite
    guard: the run has genuinely diverged, and an auto-restart would replay
    the same divergence — so the supervisor never retries this."""


def _tree_host(tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda x: np.asarray(jax.device_get(x)), tree)


def _salted_seed(seed: int, salt: int) -> int:
    """Data-pipeline seed for rollback window ``salt`` (0 = original run).

    ``batch(step)`` is a pure function of (seed, step), so bumping the salt
    after a rollback re-seeds the replayed step window deterministically —
    the same salt always yields the same token stream."""
    return seed if salt == 0 else (seed + 0x9E3779B1 * salt) & 0x7FFFFFFF


def train(arch: str, *, smoke: bool = True, steps: int = 100,
          batch: int = 8, seq: int = 128, run: Optional[RunConfig] = None,
          checkpoint_dir: str = "", resume: bool = False,
          log_every: int = 10, use_mesh: bool = True,
          proteus: bool = False, chaos: Any = None,
          max_bad_steps: int = 8, spike_factor: float = 3.0,
          spike_warmup: int = 10,
          preempt_after: Optional[int] = None) -> Dict[str, Any]:
    """One training attempt. ``chaos`` is a :class:`TrainChaosConfig` (a
    fresh monkey is built) or a :class:`TrainChaosMonkey` (shared across a
    supervisor's attempts, so fire-once faults stay fired). ``preempt_after``
    requests a clean preemption once the run first crosses that absolute
    step; a resumed run past it never re-fires."""
    print(compat.describe_support())
    cfg = get_config(arch, smoke=smoke)
    run = run or RunConfig(total_steps=steps, microbatches=1)
    shape = ShapeConfig("custom", seq_len=seq, global_batch=batch,
                        mode="train")

    mesh = mesh_lib.make_local_mesh(("data",)) if use_mesh else None
    plan = plan_sharding(cfg, shape, mesh)
    model = build_model(cfg)
    optimizer = make_optimizer(cfg.optimizer, run)

    key = jax.random.PRNGKey(run.seed)
    with use_plan(plan):
        params = init_params(model.param_specs(), key)
        opt_state = optimizer.init(params)

    monkey: Optional[TrainChaosMonkey] = None
    if isinstance(chaos, TrainChaosMonkey):
        monkey = chaos
    elif chaos is not None:
        monkey = TrainChaosMonkey(chaos, total_steps=steps)
    hook = nan_grad_hook if (monkey and monkey.nan_steps) else None
    step_fn = jax.jit(make_train_step(model, optimizer, plan, run,
                                      guard=True, grad_hook=hook),
                      donate_argnums=(0, 1))

    # -- loop state: checkpointed, restored bit-for-bit on resume -----------
    start = 0
    salt = 0                        # rollback window counter (data reseed)
    ewma: Optional[float] = None    # loss EWMA for the spike detector
    ewma_n = 0
    consec_skips = 0
    skipped_total = 0
    rollbacks = 0
    anomalies = 0
    ckpt_failures = 0
    rng_key = np.asarray(jax.device_get(key)).tolist()

    ckpt = CheckpointManager(
        checkpoint_dir, keep=run.keep_checkpoints,
        fault_hook=monkey.ckpt_fault if monkey else None) \
        if checkpoint_dir else None
    resumed_at = None
    if ckpt and resume and ckpt.latest_step() is not None:
        start, state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        loop = ckpt.load_extra(start).get("loop", {})
        salt = int(loop.get("data_salt", 0))
        ewma = loop.get("loss_ewma")
        ewma_n = int(loop.get("ewma_n", 0))
        consec_skips = int(loop.get("consec_skips", 0))
        skipped_total = int(loop.get("skipped_steps", 0))
        rollbacks = int(loop.get("rollbacks", 0))
        anomalies = int(loop.get("anomalies", 0))
        rng_key = loop.get("rng_key", rng_key)
        resumed_at = start
        print(f"resumed from step {start} (salt={salt})")

    batch_fn = make_batch_fn(cfg, shape, seed=_salted_seed(run.seed, salt))
    preempt = PreemptionHandler().install()
    straggler = StragglerMonitor()
    losses: List[float] = []
    first_step = start
    preempted = False
    t_begin = time.time()

    def loop_state(step_next: int) -> Dict[str, Any]:
        return {"step": step_next, "data_salt": salt, "loss_ewma": ewma,
                "ewma_n": ewma_n, "consec_skips": consec_skips,
                "skipped_steps": skipped_total, "rollbacks": rollbacks,
                "anomalies": anomalies, "rng_key": rng_key,
                "straggler_flags": len(straggler.flagged)}

    def save_boundary(step_next: int, loss: float) -> bool:
        nonlocal ckpt_failures
        try:
            ckpt.save(step_next, {"params": params, "opt": opt_state},
                      extra={"loss": loss, "loop": loop_state(step_next)})
            RestartManifest(
                step=step_next, checkpoint_dir=checkpoint_dir,
                mesh_shape=list(mesh.shape.values()) if mesh else [1],
                mesh_axes=list(mesh.shape.keys()) if mesh else ["data"],
                data_seed=run.seed, arch=arch, shape=shape.name,
                straggler_events=straggler.flagged,
                train=loop_state(step_next),
            ).save(os.path.join(checkpoint_dir, "manifest.json"))
            if monkey:
                monkey.maybe_tear(ckpt, step_next)
        except CheckpointWriteError as e:
            ckpt_failures += 1
            warnings.warn(f"checkpoint write failed at step {step_next} "
                          f"({e}); training continues — the previous "
                          "checkpoint still restores")
            return False
        return True

    def drain_writer() -> None:
        nonlocal ckpt_failures
        if not ckpt:
            return
        try:
            ckpt.wait()
        except CheckpointWriteError as e:
            ckpt_failures += 1
            warnings.warn(str(e))

    step = start
    while step < steps:
        straggler.step_start()
        if monkey:
            try:
                monkey.on_step(step)    # injected sleep / hard host crash
            except Exception:
                preempt.uninstall()
                drain_writer()
                raise
        b = {k: jnp.asarray(v) for k, v in batch_fn(step).items()}
        if hook is not None:
            arm = jnp.asarray(1 if monkey.nan_armed(step) else 0, jnp.int32)
            params, opt_state, metrics = step_fn(params, opt_state, b, arm)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        gnorm = float(metrics["grad_norm"])
        losses.append(loss)

        if bool(metrics["skipped"]):
            skipped_total += 1
            consec_skips += 1
            print(f"step {step:5d} SKIPPED non-finite loss/grads "
                  f"(grad_norm={gnorm:.3g}, {consec_skips} consecutive)")
            if consec_skips >= max_bad_steps:
                preempt.uninstall()
                drain_writer()
                raise TrainDivergedError(
                    f"{consec_skips} consecutive non-finite steps ending at "
                    f"step {step} (max_bad_steps={max_bad_steps})")
        else:
            consec_skips = 0
            observed = loss * (monkey.loss_scale(step, salt) if monkey
                               else 1.0)
            if (spike_factor > 0 and ewma is not None
                    and ewma_n >= spike_warmup
                    and observed > spike_factor * max(ewma, 1e-9)):
                anomalies += 1
                drain_writer()  # pending async saves must be visible, so
                                # the rollback target is deterministic
                if ckpt and ckpt.latest_step() is not None:
                    rb, state = ckpt.restore({"params": params,
                                              "opt": opt_state})
                    params, opt_state = state["params"], state["opt"]
                    loop = ckpt.load_extra(rb).get("loop", {})
                    rollbacks += 1
                    salt = int(loop.get("data_salt", 0)) + 1
                    ewma = loop.get("loss_ewma")
                    ewma_n = int(loop.get("ewma_n", 0))
                    consec_skips = int(loop.get("consec_skips", 0))
                    skipped_total = int(loop.get("skipped_steps", 0))
                    batch_fn = make_batch_fn(
                        cfg, shape, seed=_salted_seed(run.seed, salt))
                    if rb < first_step:
                        first_step = rb
                        del losses[:]
                    else:
                        del losses[rb - first_step:]
                    print(f"loss spike at step {step} ({observed:.3f} > "
                          f"{spike_factor:.1f}x EWMA): rolled back to step "
                          f"{rb}, data window re-seeded (salt={salt})")
                    step = rb
                    continue
                warnings.warn(f"loss spike at step {step} with no "
                              "checkpoint to roll back to; continuing")
            ewma = observed if ewma is None else 0.9 * ewma + 0.1 * observed
            ewma_n += 1

        flag = straggler.step_end(step)
        if flag:
            print(f"  straggler flag: {flag}")
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t_begin
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"({dt / max(step - start + 1, 1):.2f}s/step)")
        if preempt_after is not None and start < preempt_after <= step + 1:
            preempt.requested = True
        if monkey and monkey.preempt(step):
            preempt.requested = True
        if ckpt and ((step + 1) % run.checkpoint_every == 0
                     or preempt.requested or step == steps - 1):
            save_boundary(step + 1, loss)
        if preempt.requested:
            print(f"preemption requested: checkpointed at {step + 1}, "
                  "exiting cleanly")
            preempted = True
            break
        step += 1
    preempt.uninstall()
    drain_writer()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "params": params, "opt_state": opt_state,
            "first_step": first_step, "resumed_at": resumed_at,
            "preempted": preempted, "skipped_steps": skipped_total,
            "rollbacks": rollbacks, "anomalies": anomalies,
            "ckpt_failures": ckpt_failures,
            "chaos_events": list(monkey.events) if monkey else []}


class TrainSupervisor:
    """Bounded auto-restart loop around :func:`train`.

    Each attempt resumes from the last *verified* checkpoint
    (``CheckpointManager.restore`` walks back past torn/corrupt steps). An
    injected preemption or hard step crash consumes one restart;
    :class:`TrainDivergedError` is never retried — a divergence replays
    deterministically, so a restart would only burn the budget. One chaos
    monkey is shared across attempts: operational faults (preempt, crash,
    checkpoint failures/tears) fire once per supervised run, per-step data
    faults (NaN grads, spikes) replay by absolute step — together that makes
    the supervised run byte-identical to an uninterrupted one
    (:func:`verify_resume_identity`).
    """

    def __init__(self, arch: str, *, checkpoint_dir: str, steps: int,
                 max_restarts: int = 2, chaos: Any = None,
                 preempt_after: Optional[int] = None, **train_kw):
        assert checkpoint_dir, "TrainSupervisor needs a checkpoint_dir"
        self.arch = arch
        self.checkpoint_dir = checkpoint_dir
        self.steps = steps
        self.max_restarts = max_restarts
        if chaos is not None and not isinstance(chaos, TrainChaosMonkey):
            chaos = TrainChaosMonkey(chaos, total_steps=steps)
        self.monkey: Optional[TrainChaosMonkey] = chaos
        self.preempt_after = preempt_after
        self.train_kw = train_kw
        self.restarts = 0
        self.attempts: List[Dict[str, Any]] = []

    def run(self) -> Dict[str, Any]:
        while True:
            try:
                out = train(self.arch, checkpoint_dir=self.checkpoint_dir,
                            steps=self.steps, resume=True, chaos=self.monkey,
                            preempt_after=(self.preempt_after
                                           if self.restarts == 0 else None),
                            **self.train_kw)
            except TrainDivergedError:
                raise
            except Exception as e:  # noqa: BLE001 — supervisor absorbs
                self.attempts.append({"error": repr(e)})
                if self.restarts >= self.max_restarts:
                    raise
                self.restarts += 1
                print(f"supervisor: attempt {self.restarts} died ({e!r}); "
                      "restarting from the last verified checkpoint")
                continue
            self.attempts.append({"first_step": out["first_step"],
                                  "losses": list(out["losses"]),
                                  "preempted": out["preempted"]})
            if out["preempted"] and self.restarts < self.max_restarts:
                self.restarts += 1
                print(f"supervisor: preempted; restart "
                      f"{self.restarts}/{self.max_restarts}")
                continue
            out["restarts"] = self.restarts
            out["losses_full"] = self.stitched_losses()
            return out

    def stitched_losses(self) -> List[float]:
        """Per-attempt loss curves merged by absolute step (later attempts
        win — they replayed those steps from a verified checkpoint)."""
        by_step: Dict[int, float] = {}
        for a in self.attempts:
            if "losses" not in a:
                continue
            for i, loss in enumerate(a["losses"]):
                by_step[a["first_step"] + i] = loss
        return [by_step[s] for s in sorted(by_step)]


def _strip_operational(cfg: TrainChaosConfig) -> TrainChaosConfig:
    """The reference run keeps per-step data faults (NaN/slow/spike — they
    must replay identically) but drops operational faults (preemption,
    crashes, checkpoint failures/tears — the interruptions under test)."""
    return dataclasses.replace(
        cfg, preempt=-1, crash=0, ckpt_fail=0, torn=0,
        crash_steps=None, ckpt_fail_steps=None, torn_steps=None)


def verify_resume_identity(arch: str, *, steps: int, work_dir: str,
                           chaos: Optional[TrainChaosConfig] = None,
                           preempt_after: Optional[int] = None,
                           max_restarts: int = 2,
                           **train_kw) -> Dict[str, Any]:
    """The resume-identity gate: a run interrupted by preemption/crashes and
    auto-restarted by :class:`TrainSupervisor` must produce byte-identical
    losses and final params vs an uninterrupted reference run."""
    sup = TrainSupervisor(arch, checkpoint_dir=os.path.join(work_dir, "sup"),
                          steps=steps, max_restarts=max_restarts,
                          chaos=chaos, preempt_after=preempt_after,
                          **train_kw)
    out = sup.run()
    ref_chaos = _strip_operational(chaos) if chaos is not None else None
    ref = train(arch, steps=steps,
                checkpoint_dir=os.path.join(work_dir, "ref"),
                chaos=ref_chaos, **train_kw)
    losses_ok = (len(out["losses_full"]) == len(ref["losses"])
                 and np.array_equal(np.asarray(out["losses_full"]),
                                    np.asarray(ref["losses"]),
                                    equal_nan=True))
    pa = jax.tree_util.tree_leaves(_tree_host(out["params"]))
    pb = jax.tree_util.tree_leaves(_tree_host(ref["params"]))
    params_ok = len(pa) == len(pb) and all(
        a.tobytes() == b.tobytes() for a, b in zip(pa, pb))
    return {"identical": losses_ok and params_ok,
            "losses_match": losses_ok, "params_match": params_ok,
            "restarts": out["restarts"],
            "skipped_steps": out["skipped_steps"],
            "rollbacks": out["rollbacks"],
            "ckpt_failures": out["ckpt_failures"],
            "out": out, "ref": ref}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="pimref-100m", choices=list(ALL_IDS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="checkpoint interval in steps (default: RunConfig)")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="seed for the REPRO_CHAOS train fault plan (arms "
                    "a default nan+slow plan when the env var is unset)")
    ap.add_argument("--supervise", action="store_true",
                    help="wrap the run in TrainSupervisor auto-restart")
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--max-bad-steps", type=int, default=8)
    ap.add_argument("--spike-factor", type=float, default=3.0)
    ap.add_argument("--preempt-after", type=int, default=None,
                    help="request a clean preemption once this absolute "
                    "step is crossed (with --supervise / --resume-verify "
                    "the run auto-restarts)")
    ap.add_argument("--resume-verify", action="store_true",
                    help="run the interrupted+resumed vs uninterrupted "
                    "byte-identity gate and exit")
    ap.add_argument("--attn-impl", default=None,
                    choices=["auto", "pallas", "jnp"],
                    help="attention backend (sets REPRO_ATTN_IMPL before "
                    "the train step is traced)")
    ap.add_argument("--kv-quant", default=None,
                    choices=["off", "int8", "int4", "auto"],
                    help="Proteus-quantized KV cache for any eval/serve "
                    "prefill+decode launched from this process (sets "
                    "REPRO_KV_QUANT; the train step itself has no KV cache)")
    args = ap.parse_args()
    if args.attn_impl:
        os.environ["REPRO_ATTN_IMPL"] = args.attn_impl
    if args.kv_quant:
        os.environ["REPRO_KV_QUANT"] = args.kv_quant
    run = RunConfig(total_steps=args.steps, learning_rate=args.lr,
                    microbatches=1,
                    checkpoint_every=args.checkpoint_every or 200)
    chaos = TrainChaosConfig.from_env(args.chaos_seed)
    if chaos is None and args.chaos_seed is not None:
        chaos = TrainChaosConfig.parse("nan=1,slow=1", seed=args.chaos_seed)
    common: Dict[str, Any] = dict(
        smoke=args.smoke, batch=args.batch, seq=args.seq, run=run,
        max_bad_steps=args.max_bad_steps, spike_factor=args.spike_factor)
    if args.resume_verify:
        work = args.checkpoint_dir or tempfile.mkdtemp(prefix="train_verify_")
        res = verify_resume_identity(
            args.arch, steps=args.steps, work_dir=work, chaos=chaos,
            preempt_after=args.preempt_after or max(args.steps // 2, 1),
            max_restarts=args.max_restarts, **common)
        assert res["identical"], (
            f"resume-verify FAILED: losses_match={res['losses_match']} "
            f"params_match={res['params_match']}")
        print(f"resume-verify: byte-identical across {res['restarts']} "
              f"restart(s) ({res['skipped_steps']} skipped, "
              f"{res['rollbacks']} rollback(s))")
        return
    if args.supervise or (args.preempt_after is not None):
        assert args.checkpoint_dir, "--supervise needs --checkpoint-dir"
        sup = TrainSupervisor(
            args.arch, checkpoint_dir=args.checkpoint_dir, steps=args.steps,
            max_restarts=args.max_restarts, chaos=chaos,
            preempt_after=args.preempt_after, **common)
        out = sup.run()
        print(f"supervisor: {out['restarts']} restart(s), "
              f"{out['skipped_steps']} skipped step(s), "
              f"{out['rollbacks']} rollback(s)")
    else:
        out = train(args.arch, steps=args.steps,
                    checkpoint_dir=args.checkpoint_dir, resume=args.resume,
                    chaos=chaos, **common)
    if chaos is not None and (chaos.nan or chaos.nan_steps):
        assert out["skipped_steps"] >= 1, \
            "chaos armed NaN grads but no step was skipped"
        print(f"chaos: survived {out['skipped_steps']} skipped step(s), "
              f"{out['rollbacks']} rollback(s), "
              f"{len(out['chaos_events'])} injected event(s)")
    if out["final_loss"] is None:
        print(f"nothing to do: resumed at step {out['resumed_at']}, "
              f"already past --steps {args.steps}")
    else:
        print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
