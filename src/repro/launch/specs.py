"""ShapeDtypeStruct stand-ins for every model input (dry-run path).

Weak-type-correct, shardable, no device allocation. ``input_specs`` covers
train/prefill batches; decode cells additionally take the cache specs from
``jax.eval_shape`` over the model's ``init_cache``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.mimdram import Plan


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Batch stand-ins for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct(s, jnp.int32)
    f32 = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)

    if shape.mode == "decode":
        batch: Dict[str, Any] = {"tokens": tok((B, 1))}
        return batch

    batch = {"tokens": tok((B, S))}
    if shape.mode == "train":
        batch["labels"] = tok((B, S))
    if cfg.family == "vlm":
        P = min(cfg.num_patches, S // 2)
        batch["tokens"] = tok((B, S - P))
        if shape.mode == "train":
            batch["labels"] = tok((B, S - P))
        batch["patch_embeds"] = f32((B, P, cfg.d_model))
    if cfg.family == "audio":
        batch["src_embeds"] = f32((B, int(S * cfg.src_len_ratio), cfg.d_model))
    return batch


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, plan: Plan) -> Dict:
    """PartitionSpec tree matching input_specs."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        if v.ndim == 2:
            out[k] = plan.spec("act_batch", "act_seq" if shape.mode != "decode"
                               else None)
        else:
            out[k] = plan.spec("act_batch", "act_seq", "act_embed")
    return out


def cache_specs(model, shape: ShapeConfig, max_len: Optional[int] = None) -> Any:
    """Abstract KV/state cache via eval_shape (no allocation).

    ``max_len`` overrides the cache capacity (serving pre-sizes the cache to
    prompt_len + gen so prefill -> decode needs no repad).
    """
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max_len or shape.seq_len))


def prefill_cache_specs(model, cfg: ModelConfig, shape: ShapeConfig,
                        max_len: int) -> Any:
    """Abstract pre-sized cache as actually produced by ``model.prefill``.

    Unlike :func:`cache_specs` (the ``init_cache`` template), this traces the
    prefill itself, so source-length-dependent leaves (enc-dec cross caches)
    get their exact shapes. Used by the serving engine to build per-slot
    insert targets.
    """
    from repro.models import module as mod  # noqa: PLC0415 (cycle-free import)

    abstract_p = mod.abstract_params(model.param_specs())
    batch = input_specs(cfg, shape)
    _, cache = jax.eval_shape(
        lambda p, b: model.prefill(p, b, max_len=max_len), abstract_p, batch)
    return cache


def cache_pspecs(model, plan: Plan, shape: Optional[ShapeConfig] = None) -> Any:
    axes_tree = model.cache_logical_axes()
    if shape is not None:
        shapes_tree = cache_specs(model, shape)
        return jax.tree_util.tree_map(
            lambda axes, sd: plan.spec(*axes, dims=sd.shape), axes_tree,
            shapes_tree, is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree_util.tree_map(
        lambda axes: plan.spec(*axes), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))
