"""jit'd wrappers for narrow-value detection / int4 packing."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.narrow_value.kernel import (pack_int4_kernel,
                                               required_bits_kernel,
                                               unpack_int4_kernel)


@partial(jax.jit, static_argnames=("block", "interpret"))
def required_bits(x: jax.Array, block: int = 256,
                  interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = use_interpret()
    return required_bits_kernel(x, block, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def pack_int4(v: jax.Array, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = use_interpret()
    return pack_int4_kernel(v, interpret=interpret)


@partial(jax.jit, static_argnames=("interpret",))
def unpack_int4(p: jax.Array, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = use_interpret()
    return unpack_int4_kernel(p, interpret=interpret)
