"""Narrow-value detection + int4 packing Pallas kernels (Proteus DBPE).

The thesis' Dynamic Bit-Precision Engine scans operand rows for leading
zeros/ones to find the narrowest safe width. TPU form: a per-block maximum-
magnitude scan (``required_bits``) feeding the representation selector, and
an exact nibble-packing kernel for the int4 wire format used by quantized
collectives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import import_pallas

pl = import_pallas()


def _bits_kernel(x_ref, o_ref):
    """Per-block required two's-complement width for int32 data."""
    x = x_ref[...]
    m = jnp.abs(x.astype(jnp.float32)).max()
    # bits = ceil(log2(m+1)) + 1 (sign); m=0 -> 1
    bits = jnp.where(
        m == 0, 1.0, jnp.ceil(jnp.log2(m + 1.0)) + 1.0)
    o_ref[0] = bits.astype(jnp.int32)


def required_bits_kernel(x: jax.Array, block: int = 256, *,
                         interpret: bool = True) -> jax.Array:
    """x: int32 flat (N,), N % block == 0 -> per-block widths (N//block,)."""
    n = x.shape[0]
    assert n % block == 0
    nb = n // block
    return pl.pallas_call(
        _bits_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=interpret,
    )(x)


def _pack4_kernel(v_ref, o_ref):
    v = v_ref[...]
    lo = (v[0::2] & 0x0F).astype(jnp.uint8)
    hi = (v[1::2] & 0x0F).astype(jnp.uint8)
    o_ref[...] = (lo | (hi << 4)).astype(jnp.int8)


def _unpack4_kernel(p_ref, o_ref):
    pu = p_ref[...].astype(jnp.uint8)
    lo = (pu & 0x0F).astype(jnp.int8)
    hi = ((pu >> 4) & 0x0F).astype(jnp.int8)
    sx = lambda t: jnp.where(t >= 8, t - 16, t).astype(jnp.int8)
    out = jnp.stack([sx(lo), sx(hi)], axis=-1).reshape(-1)
    o_ref[...] = out


def pack_int4_kernel(v: jax.Array, block: int = 512, *,
                     interpret: bool = True) -> jax.Array:
    """v: int8 codes in [-8, 7], flat (N,), N even -> packed (N//2,) int8."""
    n = v.shape[0]
    assert n % 2 == 0
    b = min(block, n)
    assert n % b == 0
    return pl.pallas_call(
        _pack4_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((b // 2,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // 2,), jnp.int8),
        interpret=interpret,
    )(v)


def unpack_int4_kernel(p: jax.Array, block: int = 256, *,
                       interpret: bool = True) -> jax.Array:
    n = p.shape[0]
    b = min(block, n)
    assert n % b == 0
    return pl.pallas_call(
        _unpack4_kernel,
        grid=(n // b,),
        in_specs=[pl.BlockSpec((b,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2 * b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((2 * n,), jnp.int8),
        interpret=interpret,
    )(p)
