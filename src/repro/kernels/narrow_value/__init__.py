from repro.kernels.narrow_value.ops import pack_int4, required_bits, unpack_int4
from repro.kernels.narrow_value.ref import (pack_int4_ref, required_bits_ref,
                                            unpack_int4_ref)

__all__ = ["required_bits", "pack_int4", "unpack_int4", "required_bits_ref",
           "pack_int4_ref", "unpack_int4_ref"]
