"""Pure-jnp oracles for the narrow-value kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def required_bits_ref(x: jax.Array, block: int = 256) -> jax.Array:
    n = x.shape[0]
    m = jnp.abs(x.reshape(n // block, block).astype(jnp.float32)).max(axis=1)
    return jnp.where(m == 0, 1,
                     (jnp.ceil(jnp.log2(m + 1.0)) + 1.0).astype(jnp.int32))


def pack_int4_ref(v: jax.Array) -> jax.Array:
    lo = (v[0::2] & 0x0F).astype(jnp.uint8)
    hi = (v[1::2] & 0x0F).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4_ref(p: jax.Array) -> jax.Array:
    pu = p.astype(jnp.uint8)
    lo = (pu & 0x0F).astype(jnp.int8)
    hi = ((pu >> 4) & 0x0F).astype(jnp.int8)
    sx = lambda t: jnp.where(t >= 8, t - 16, t).astype(jnp.int8)
    return jnp.stack([sx(lo), sx(hi)], axis=-1).reshape(-1)
