"""Pure-jnp oracles for the narrow-value kernels.

The int4 nibble pack/unpack oracle is the shared canonical implementation in
``repro.kernels.common`` (also re-exported by ``repro.core.proteus``) — the
Pallas kernels in ``kernel.py`` are its hardware lowering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import pack_int4, unpack_int4


def required_bits_ref(x: jax.Array, block: int = 256) -> jax.Array:
    n = x.shape[0]
    m = jnp.abs(x.reshape(n // block, block).astype(jnp.float32)).max(axis=1)
    return jnp.where(m == 0, 1,
                     (jnp.ceil(jnp.log2(m + 1.0)) + 1.0).astype(jnp.int32))


def pack_int4_ref(v: jax.Array) -> jax.Array:
    return pack_int4(v)


def unpack_int4_ref(p: jax.Array) -> jax.Array:
    return unpack_int4(p)
