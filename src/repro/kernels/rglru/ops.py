"""jit'd wrapper for the RG-LRU recurrence kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.rglru.kernel import rglru_scan_kernel


@partial(jax.jit, static_argnames=("block_t", "interpret"))
def rglru_scan(a: jax.Array, b: jax.Array, block_t: int = 128,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = use_interpret()
    return rglru_scan_kernel(a.astype(jnp.float32), b.astype(jnp.float32),
                             block_t=block_t, interpret=interpret)
