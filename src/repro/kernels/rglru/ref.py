"""Pure-jnp oracle: associative-scan linear recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + b_t over axis 1; h_{-1} = 0."""

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h
