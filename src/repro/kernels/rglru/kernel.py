"""Blocked RG-LRU linear-recurrence Pallas kernel (recurrentgemma hot loop).

h_t = a_t * h_{t-1} + b_t, with (a, b) precomputed by the gate projections.
Grid: (batch, time_blocks) — time minor (sequential); the hidden state is
carried across time blocks in VMEM scratch, so the recurrence's working set
never leaves VMEM within a block (the PIM-style locality win; the jnp
associative_scan materializes log-depth intermediates in HBM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import import_pallas, import_pallas_tpu

pl = import_pallas()
pltpu = import_pallas_tpu()  # None when this install lacks TPU pallas


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_t: int):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]                                  # (bt, d) fp32
    b = b_ref[0]
    h = h_ref[...]                                # (d,)

    def step(t, carry):
        h, out = carry
        h = a[t] * h + b[t]
        out = jax.lax.dynamic_update_index_in_dim(out, h, t, 0)
        return h, out

    out0 = jnp.zeros_like(b)
    h, out = jax.lax.fori_loop(0, block_t, step, (h, out0))
    h_ref[...] = h
    o_ref[0, ...] = out


def rglru_scan_kernel(a: jax.Array, b: jax.Array, *, block_t: int = 128,
                      interpret: bool = True) -> jax.Array:
    """a, b: (B, T, D) fp32 -> h sequence (B, T, D)."""
    B, T, D = a.shape
    bt = min(block_t, T)
    assert T % bt == 0
    nt = T // bt
    kernel = functools.partial(_rglru_kernel, block_t=bt)
    return pl.pallas_call(
        kernel,
        grid=(B, nt),
        in_specs=[
            pl.BlockSpec((1, bt, D), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bt, D), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, D), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((D,), jnp.float32)],
        interpret=interpret,
    )(a, b)
