from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref

__all__ = ["rglru_scan", "rglru_scan_ref"]
