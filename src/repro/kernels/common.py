"""Shared kernel utilities: interpret-mode / attention-backend / kv-quant
selection, pad-to-block-multiple helpers (one sentinel convention for every
caller), and the canonical int4 nibble pack/unpack pair."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

ATTN_IMPLS = ("auto", "pallas", "jnp")
KV_QUANT_MODES = ("off", "int8", "int4", "auto")
SPEC_DECODE_MODES = ("off", "ngram", "draft")


def pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to length ``to`` (no-op if already)."""
    if x.shape[axis] == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pads)


def pad_positions(pos: jax.Array, to: int) -> jax.Array:
    """Pad the last axis of an int position array ((N,) or (B, N)) up to
    ``to`` with the -1 sentinel every mask treats as invalid/empty."""
    if pos.shape[-1] == to:
        return pos
    pads = [(0, 0)] * (pos.ndim - 1) + [(0, to - pos.shape[-1])]
    return jnp.pad(pos, pads, constant_values=-1)


def use_interpret() -> bool:
    """Pallas interpret mode: on unless running on a real TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] != "0"
    return jax.default_backend() != "tpu"


def kv_quant_mode() -> str:
    """KV-cache representation for the serving hot path (Proteus runtime).

    ``REPRO_KV_QUANT=off|int8|int4|auto``: ``off`` (default) keeps the bf16
    cache; ``int8``/``int4`` store block-scaled codes + per-row fp32 scales
    (int4 nibble-packed); ``auto`` keeps int8 storage but picks the
    quantization grid per tensor data-aware (narrow-value detection). Read at
    trace time, like ``REPRO_ATTN_IMPL``: set the knob before building jitted
    programs (the launchers plumb ``--kv-quant`` here).
    """
    v = os.environ.get("REPRO_KV_QUANT", "off").lower()
    if v not in KV_QUANT_MODES:
        raise ValueError(
            f"REPRO_KV_QUANT={v!r}: expected one of {KV_QUANT_MODES}")
    return v


# ---------------------------------------------------------------------------
# int4 nibble packing — the one shared implementation (re-exported by
# repro.core.proteus and repro.kernels.narrow_value.ref; the Pallas kernels
# in kernels/narrow_value are the hardware lowering tested against these).
# Pure jnp, so no new version-sensitive Pallas entry point is needed.
# ---------------------------------------------------------------------------
def pack_int4(v: jax.Array) -> jax.Array:
    """Pack int8-held int4 codes (pairs along the last axis) into one int8
    byte each; exact roundtrip with :func:`unpack_int4`."""
    assert v.shape[-1] % 2 == 0, v.shape
    lo = (v[..., 0::2] & 0x0F).astype(jnp.uint8)
    hi = (v[..., 1::2] & 0x0F).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: sign-extended int8 codes in [-8, 7]."""
    pu = p.astype(jnp.uint8)
    lo = (pu & 0x0F).astype(jnp.int8)
    hi = ((pu >> 4) & 0x0F).astype(jnp.int8)
    sx = lambda t: jnp.where(t >= 8, t - 16, t).astype(jnp.int8)
    out = jnp.stack([sx(lo), sx(hi)], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,))


def kv_page_size() -> int:
    """KV-cache page size for the paged (block-table) serving cache.

    ``REPRO_KV_PAGES=<tokens-per-page>``: 0 (default) keeps the contiguous
    per-slot ring cache; a positive value switches ``kv_cache_init`` & co.
    to the :class:`~repro.models.layers.PagedKVCache` layout — one pooled
    page array plus per-slot int32 page tables — which the serving engine
    pairs with a free-list allocator and hash-consed prefix sharing. Read at
    trace time, like ``REPRO_KV_QUANT``: set the knob before building jitted
    programs (the launchers plumb ``--kv-page-size`` here).
    """
    v = os.environ.get("REPRO_KV_PAGES", "0")
    try:
        ps = int(v)
    except ValueError:
        raise ValueError(f"REPRO_KV_PAGES={v!r}: expected a non-negative int")
    if ps < 0:
        raise ValueError(f"REPRO_KV_PAGES={v!r}: expected a non-negative int")
    return ps


def spec_decode_mode() -> str:
    """Speculative-decoding drafter for the fused decode scan.

    ``REPRO_SPEC_DECODE=off|ngram|draft``: ``off`` (default) decodes one token
    per scan iteration; ``ngram`` drafts ``spec_draft_len()`` tokens per
    iteration by device-side bigram suffix lookup over the slot's
    prompt+emitted history and verifies them in one multi-query decode pass;
    ``draft`` drafts with a layer-skip pass through the target model's own
    first layers (self-speculative — the draft shares the engine's cache
    machinery literally: same params, same KV cache). Read at trace time, like
    ``REPRO_ATTN_IMPL``: set the knob before building jitted programs (the
    launchers plumb ``--spec-decode`` here). Greedy output is byte-identical
    with speculation on or off.
    """
    v = os.environ.get("REPRO_SPEC_DECODE", "off").lower()
    if v not in SPEC_DECODE_MODES:
        raise ValueError(
            f"REPRO_SPEC_DECODE={v!r}: expected one of {SPEC_DECODE_MODES}")
    return v


def spec_draft_len() -> int:
    """Static draft length k for speculative decoding (``REPRO_SPEC_K``,
    default 3): each fused-scan iteration verifies a (k+1)-token block —
    the fed token plus k drafts — and commits 1..k+1 tokens."""
    v = os.environ.get("REPRO_SPEC_K", "3")
    try:
        k = int(v)
    except ValueError:
        raise ValueError(f"REPRO_SPEC_K={v!r}: expected a positive int")
    if k < 1:
        raise ValueError(f"REPRO_SPEC_K={v!r}: expected a positive int")
    return k


def spec_draft_layers() -> int:
    """Layer budget for the ``draft`` (layer-skip self-drafting) mode:
    ``REPRO_SPEC_DRAFT_LAYERS`` (default 0 = half the target's layers,
    at least one)."""
    v = os.environ.get("REPRO_SPEC_DRAFT_LAYERS", "0")
    try:
        n = int(v)
    except ValueError:
        raise ValueError(
            f"REPRO_SPEC_DRAFT_LAYERS={v!r}: expected a non-negative int")
    if n < 0:
        raise ValueError(
            f"REPRO_SPEC_DRAFT_LAYERS={v!r}: expected a non-negative int")
    return n


def attn_impl() -> str:
    """Attention backend for ``chunked_attention``: 'pallas' or 'jnp'.

    ``REPRO_ATTN_IMPL=pallas|jnp|auto`` (default auto = compiled Pallas on
    TPU, jnp elsewhere). ``pallas`` off-TPU runs in interpret mode unless
    ``REPRO_PALLAS_INTERPRET=0``. Read at trace time: set the knob before
    building jitted programs (the launchers plumb ``--attn-impl`` here).
    """
    v = os.environ.get("REPRO_ATTN_IMPL", "auto").lower()
    if v not in ATTN_IMPLS:
        raise ValueError(
            f"REPRO_ATTN_IMPL={v!r}: expected one of {ATTN_IMPLS}")
    if v == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return v
