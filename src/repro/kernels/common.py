"""Shared kernel utilities: interpret-mode / attention-backend selection and
pad-to-block-multiple helpers (one sentinel convention for every caller)."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

ATTN_IMPLS = ("auto", "pallas", "jnp")


def pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to length ``to`` (no-op if already)."""
    if x.shape[axis] == to:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, to - x.shape[axis])
    return jnp.pad(x, pads)


def pad_positions(pos: jax.Array, to: int) -> jax.Array:
    """Pad the last axis of an int position array ((N,) or (B, N)) up to
    ``to`` with the -1 sentinel every mask treats as invalid/empty."""
    if pos.shape[-1] == to:
        return pos
    pads = [(0, 0)] * (pos.ndim - 1) + [(0, to - pos.shape[-1])]
    return jnp.pad(pos, pads, constant_values=-1)


def use_interpret() -> bool:
    """Pallas interpret mode: on unless running on a real TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] != "0"
    return jax.default_backend() != "tpu"


def attn_impl() -> str:
    """Attention backend for ``chunked_attention``: 'pallas' or 'jnp'.

    ``REPRO_ATTN_IMPL=pallas|jnp|auto`` (default auto = compiled Pallas on
    TPU, jnp elsewhere). ``pallas`` off-TPU runs in interpret mode unless
    ``REPRO_PALLAS_INTERPRET=0``. Read at trace time: set the knob before
    building jitted programs (the launchers plumb ``--attn-impl`` here).
    """
    v = os.environ.get("REPRO_ATTN_IMPL", "auto").lower()
    if v not in ATTN_IMPLS:
        raise ValueError(
            f"REPRO_ATTN_IMPL={v!r}: expected one of {ATTN_IMPLS}")
    if v == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return v
