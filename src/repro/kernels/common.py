"""Shared kernel utilities: interpret-mode selection."""
from __future__ import annotations

import os

import jax


def use_interpret() -> bool:
    """Pallas interpret mode: on unless running on a real TPU."""
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return os.environ["REPRO_PALLAS_INTERPRET"] != "0"
    return jax.default_backend() != "tpu"
