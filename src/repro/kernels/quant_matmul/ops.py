"""jit'd public wrappers for the Proteus quantized matmul."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.quant_matmul.kernel import quant_matmul_kernel
from repro.kernels.quant_matmul.ref import quantize_weights_ref


@partial(jax.jit, static_argnames=("block_k", "bits"))
def quantize_weights(w: jax.Array, block_k: int = 128, bits: int = 8):
    return quantize_weights_ref(w, block_k=block_k, bits=bits)


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                   "interpret"))
def quant_matmul(x: jax.Array, codes: jax.Array, scales: jax.Array, *,
                 block_m: int = 128, block_n: int = 128, block_k: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = use_interpret()
    return quant_matmul_kernel(x, codes, scales, block_m=block_m,
                               block_n=block_n, block_k=block_k,
                               interpret=interpret)
