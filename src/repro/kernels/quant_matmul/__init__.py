from repro.kernels.quant_matmul.ops import quant_matmul, quantize_weights
from repro.kernels.quant_matmul.ref import (dequantize_ref, quant_matmul_ref,
                                            quantize_weights_ref)

__all__ = ["quant_matmul", "quantize_weights", "quant_matmul_ref",
           "quantize_weights_ref", "dequantize_ref"]
