"""Pure-jnp oracle: block-dequantize then matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_weights_ref(w: jax.Array, block_k: int = 128, bits: int = 8):
    """w: (K, N) -> (codes int8 (K,N), scales (K//block_k, N))."""
    K, N = w.shape
    assert K % block_k == 0
    qmax = float(2 ** (bits - 1) - 1)
    wb = w.reshape(K // block_k, block_k, N).astype(jnp.float32)
    maxabs = jnp.abs(wb).max(axis=1)                       # (nkb, N)
    scale = jnp.where(maxabs == 0, 1.0, maxabs / qmax)
    codes = jnp.clip(jnp.round(wb / scale[:, None, :]), -qmax - 1, qmax)
    return codes.reshape(K, N).astype(jnp.int8), scale


def dequantize_ref(codes: jax.Array, scales: jax.Array) -> jax.Array:
    K, N = codes.shape
    nkb = scales.shape[0]
    cb = codes.reshape(nkb, K // nkb, N).astype(jnp.float32)
    return (cb * scales[:, None, :]).reshape(K, N)


def quant_matmul_ref(x: jax.Array, codes: jax.Array,
                     scales: jax.Array) -> jax.Array:
    w = dequantize_ref(codes, scales)
    return x.astype(jnp.float32) @ w
