"""Block-scaled int8 matmul Pallas kernel (Proteus arithmetic engine).

The TPU-native form of Proteus' adaptive-representation arithmetic: weights
are stored as int8 codes with per-(K-block, N-column) fp32 scales — the
block-scaled representation that replaces RBR (DESIGN.md §6). The kernel
dequantizes in VMEM registers (scales applied to the fp32 accumulator), so
HBM traffic for weights is 2x(int8) / 4x(int4-packed) lower than bf16/fp32.

Grid: (m_blocks, n_blocks, k_blocks), k minor (sequential); fp32 accumulator
in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro.compat import import_pallas, import_pallas_tpu

pl = import_pallas()
pltpu = import_pallas_tpu()  # None when this install lacks TPU pallas


def _qmm_kernel(x_ref, wq_ref, scale_ref, o_ref, acc_ref, *, n_k_blocks: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, bk)
    wq = wq_ref[...].astype(jnp.float32)          # (bk, bn) int8 codes
    scale = scale_ref[...]                        # (1, bn) fp32, this k-block
    part = jax.lax.dot_general(x, wq, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    acc_ref[...] += part * scale

    @pl.when(ki == n_k_blocks - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def quant_matmul_kernel(x: jax.Array, wq: jax.Array, scales: jax.Array, *,
                        block_m: int = 128, block_n: int = 128,
                        block_k: int = 128, out_dtype=jnp.float32,
                        interpret: bool = True) -> jax.Array:
    """x: (M, K) float; wq: (K, N) int8; scales: (K//block_k, N) fp32."""
    M, K = x.shape
    K2, N = wq.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0
    assert scales.shape == (K // bk, N), (scales.shape, K // bk, N)
    nm, nn, nk = M // bm, N // bn, K // bk

    kernel = functools.partial(_qmm_kernel, n_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, wq, scales)
