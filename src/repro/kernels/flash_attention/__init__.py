from repro.kernels.flash_attention.kernel import (flash_attention_bh,
                                                 flash_attention_fwd,
                                                 flash_decode_fwd)
from repro.kernels.flash_attention.ops import (flash_attention,
                                               flash_attention_gqa_fwd,
                                               flash_decode)
from repro.kernels.flash_attention.ref import attention_ref_bh

__all__ = ["flash_attention", "flash_attention_gqa_fwd", "flash_decode",
           "flash_attention_bh", "flash_attention_fwd", "flash_decode_fwd",
           "attention_ref_bh"]
