"""Flash attention Pallas TPU kernels: tiled online softmax, GQA-native.

Processing-using-memory principle on the HBM->VMEM hierarchy: the score tile
lives only in VMEM; scores never round-trip to HBM (the jnp chunked path
materializes them — this kernel removes the dominant memory-term contribution
found by DAMOV for train/prefill cells, and the KV-stream term for decode).

Two entry points share one tile-update body:

* ``flash_attention_fwd`` — prefill/train. Grid ``(B, Hkv, nq, nk)``, kv
  minor => sequential on TPU; the ``G = Hq // Hkv`` grouped query heads of
  one kv head ride in the q block, so each (k, v) tile is fetched from HBM
  once per kv head, not once per query head (GQA without materializing
  ``jnp.repeat`` copies). Emits ``(out, lse)`` so a recompute backward can
  run without saved score tiles.
* ``flash_decode_fwd`` — serving. Small q (the fused-decode chunk step)
  against the ring KV cache; grid ``(B, Hkv, nk)`` over kv blocks only, the
  whole (G, S) query block resident in VMEM across the kv stream.
* ``flash_decode_quant_fwd`` — serving over a Proteus-quantized KV cache:
  the kv BlockSpecs carry int8 (or nibble-packed int4) codes + per-row fp32
  scales and dequantize per tile in VMEM, cutting the dominant decode HBM
  stream ~2x/~4x.

Fully-masked kv tiles (max position sentinel == -1: dead ring slots, pad
tiles) are skipped inside every kernel — the block-sparse analogue of the
jnp path's ``attn_block_skip``.

Masking is position-based everywhere: per-row absolute q positions
``(B, S)`` and per-slot kv positions ``(B, T)`` (-1 = empty/invalid slot)
subsume causal/window/ring-cache/valid-length and pad-to-block masking in
one rule, so both kernels serve every model family and the serving engine.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.compat import (import_pallas, pallas_prefetch_grid_spec,
                          pallas_vmem_scratch)
from repro.kernels.common import pad_axis, unpack_int4

pl = import_pallas()

NEG_INF = -1e30


def _tile_update(q, k, v, qp, kp, m_ref, l_ref, acc_ref, *, scale: float,
                 causal: bool, window: int, softcap: float):
    """One (G, bq) x (bk) online-softmax update.

    q: (G, bq, D) f32   k/v: (bk, D) f32
    qp: (bq,) int32 absolute q positions (-1 = padded row)
    kp: (bk,) int32 absolute kv positions (-1 = empty/padded/invalid slot)
    m/l: (G, bq) f32 scratch   acc: (G, bq, D) f32 scratch
    """
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    mask = (kp[None, :] >= 0) & (qp[:, None] >= 0)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    mb = mask[None]                                    # (1, bq, bk)
    s = jnp.where(mb, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=2))
    # guard fully-masked rows: exp(NEG_INF - NEG_INF) would be 1
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - safe_m[..., None])
    p = jnp.where(mb, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    l_new = l_ref[...] * alpha + p.sum(axis=2)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jax.lax.dot_general(
        p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new


def _tile_init(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _tile_finalize(o_ref, lse_ref, m_ref, l_ref, acc_ref):
    l = l_ref[...]
    lsafe = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, 0] = (acc_ref[...] / lsafe[..., None]).astype(o_ref.dtype)
    m = m_ref[...]
    lse_ref[0, 0] = jnp.where(m <= NEG_INF / 2, NEG_INF, m + jnp.log(lsafe))


def _flash_kernel(qp_ref, kp_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
                  window: int, softcap: float, kv_axis: int, n_kv: int):
    ki = pl.program_id(kv_axis)

    @pl.when(ki == 0)
    def _init():
        _tile_init(m_ref, l_ref, acc_ref)

    kp = kp_ref[0]

    # block-sparse kv-tile skip: the -1 sentinel marks empty/invalid slots,
    # so a tile whose max position is -1 is fully masked (dead ring slots,
    # pad-to-block tiles) and contributes nothing — skip the dot/exp work.
    @pl.when(jnp.max(kp) >= 0)
    def _update():
        _tile_update(q_ref[0, 0].astype(jnp.float32),
                     k_ref[0, 0].astype(jnp.float32),
                     v_ref[0, 0].astype(jnp.float32),
                     qp_ref[0], kp, m_ref, l_ref, acc_ref,
                     scale=scale, causal=causal, window=window,
                     softcap=softcap)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _tile_finalize(o_ref, lse_ref, m_ref, l_ref, acc_ref)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_positions: jax.Array, kv_positions: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """Prefill/train kernel. Shapes (already padded to block multiples):

    q: (B, Hkv, G, S, D)   k/v: (B, Hkv, T, D)
    q_positions: (B, S) int32   kv_positions: (B, T) int32 (-1 = masked)
    Returns (out (B, Hkv, G, S, D), lse (B, Hkv, G, S) f32).
    """
    B, Hkv, G, S, D = q.shape
    T = k.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        softcap=softcap, kv_axis=3, n_kv=nk)
    out, lse = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        ],
        scratch_shapes=[
            pallas_vmem_scratch((G, bq), jnp.float32),
            pallas_vmem_scratch((G, bq), jnp.float32),
            pallas_vmem_scratch((G, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
    return out, lse


def flash_decode_fwd(q: jax.Array, k: jax.Array, v: jax.Array,
                     q_positions: jax.Array, kv_positions: jax.Array, *,
                     causal: bool = True, window: int = 0,
                     softcap: float = 0.0, block_k: int = 128,
                     interpret: bool = True) -> jax.Array:
    """Decode kernel: the serving engine's per-chunk inner loop.

    The whole small-q block (one scan step of the fused decode loop) stays in
    VMEM while the ring KV cache streams through; grid over kv blocks only.

    q: (B, Hkv, G, S, D) with small S   k/v: (B, Hkv, T, D), T % block_k == 0
    q_positions: (B, S) per-sequence positions (continuous batching)
    kv_positions: (B, T) per-slot ring-cache positions (-1 = empty slot)
    Returns out (B, Hkv, G, S, D).
    """
    B, Hkv, G, S, D = q.shape
    T = k.shape[2]
    bk = min(block_k, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(D), causal=causal, window=window,
        softcap=softcap, kv_axis=2, n_kv=nk)
    out, _ = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, S), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j: (b, h, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, S), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        ],
        scratch_shapes=[
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
    return out


def _dequant_rows(codes: jax.Array, scale: jax.Array, d: int) -> jax.Array:
    """Dequantize one kv tile in VMEM: codes (bk, Dc) int8 + per-row scales
    (bk,) fp32 -> (bk, d) fp32. Dc == d//2 means nibble-packed int4 codes
    (unpacked in registers via the shared helper — HBM only ever saw the
    packed bytes)."""
    if codes.shape[-1] != d:
        assert codes.shape[-1] * 2 == d, (codes.shape, d)
        codes = unpack_int4(codes)
    return codes.astype(jnp.float32) * scale[:, None]


def _flash_decode_quant_kernel(qp_ref, kp_ref, q_ref, kq_ref, ks_ref, vq_ref,
                               vs_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref,
                               *, scale: float, causal: bool, window: int,
                               softcap: float, n_kv: int, head_dim: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _tile_init(m_ref, l_ref, acc_ref)

    kp = kp_ref[0]

    @pl.when(jnp.max(kp) >= 0)          # block-sparse skip of dead kv tiles
    def _update():
        k = _dequant_rows(kq_ref[0, 0], ks_ref[0, 0], head_dim)
        v = _dequant_rows(vq_ref[0, 0], vs_ref[0, 0], head_dim)
        _tile_update(q_ref[0, 0].astype(jnp.float32), k, v,
                     qp_ref[0], kp, m_ref, l_ref, acc_ref,
                     scale=scale, causal=causal, window=window,
                     softcap=softcap)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _tile_finalize(o_ref, lse_ref, m_ref, l_ref, acc_ref)


def flash_decode_quant_fwd(q: jax.Array, k_codes: jax.Array,
                           k_scale: jax.Array, v_codes: jax.Array,
                           v_scale: jax.Array, q_positions: jax.Array,
                           kv_positions: jax.Array, *, causal: bool = True,
                           window: int = 0, softcap: float = 0.0,
                           block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """Decode kernel over a Proteus-quantized ring KV cache.

    The KV stream — the dominant HBM term of decode — is read as int8 codes
    (optionally nibble-packed int4, ``Dc == D//2``) plus per-(slot, head)
    fp32 scales, and dequantized per tile **in VMEM**: HBM traffic drops
    ~2x (int8) / ~4x (int4) vs the bf16 cache while the math runs fp32.

    q: (B, Hkv, G, S, D)   k/v codes: (B, Hkv, T, Dc) int8
    k/v scale: (B, Hkv, T) fp32      q_positions: (B, S) int32
    kv_positions: (B, T) int32 (-1 = empty slot)  ->  out (B, Hkv, G, S, D).
    """
    B, Hkv, G, S, D = q.shape
    T = k_codes.shape[2]
    Dc = k_codes.shape[3]
    bk = min(block_k, T)
    assert T % bk == 0, (T, bk)
    nk = T // bk
    kernel = functools.partial(
        _flash_decode_quant_kernel, scale=1.0 / math.sqrt(D), causal=causal,
        window=window, softcap=softcap, n_kv=nk, head_dim=D)
    out, _ = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, S), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, bk), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, bk, Dc), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk), lambda b, h, j: (b, h, j)),
            pl.BlockSpec((1, 1, bk, Dc), lambda b, h, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk), lambda b, h, j: (b, h, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, S), lambda b, h, j: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        ],
        scratch_shapes=[
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k_codes, k_scale, v_codes, v_scale)
    return out


# ---------------------------------------------------------------------------
# Paged decode kernels (block-table KV cache)
#
# Flash-decoding split-KV over *pages*: the grid runs over each slot's
# logical pages and a scalar-prefetched page table resolves logical page ->
# physical pool row inside the kv BlockSpec index maps, so the kernel streams
# exactly the pages a slot owns straight from the shared pool — no gather
# materializing a dense per-slot copy in HBM. The pool keeps its storage
# layout (P, ps, H, D); only one (ps, D) page per kv head moves to VMEM per
# grid step. Masking stays purely positional: the (B, NP*ps) kv_positions
# carry the ring/pad/-1 sentinels, so trash-page tiles are either skipped
# (all -1) or causally masked.
# ---------------------------------------------------------------------------
def _flash_decode_paged_kernel(tab_ref, qp_ref, kp_ref, q_ref, k_ref, v_ref,
                               o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                               scale: float, causal: bool, window: int,
                               softcap: float, n_kv: int):
    del tab_ref  # consumed by the BlockSpec index maps (scalar prefetch)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _tile_init(m_ref, l_ref, acc_ref)

    kp = kp_ref[0]

    @pl.when(jnp.max(kp) >= 0)          # skip dead pages (trash / unwritten)
    def _update():
        _tile_update(q_ref[0, 0].astype(jnp.float32),
                     k_ref[0, :, 0].astype(jnp.float32),
                     v_ref[0, :, 0].astype(jnp.float32),
                     qp_ref[0], kp, m_ref, l_ref, acc_ref,
                     scale=scale, causal=causal, window=window,
                     softcap=softcap)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _tile_finalize(o_ref, lse_ref, m_ref, l_ref, acc_ref)


def flash_decode_paged_fwd(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, table: jax.Array,
                           q_positions: jax.Array, kv_positions: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           softcap: float = 0.0,
                           interpret: bool = True) -> jax.Array:
    """Paged decode kernel.

    q: (B, Hkv, G, S, D) with small S   k/v pool: (P, ps, Hkv, D)
    table: (B, NP) int32 physical pool rows (page 0 = trash page)
    q_positions: (B, S)   kv_positions: (B, NP * ps) (-1 = empty/invalid)
    Returns out (B, Hkv, G, S, D).
    """
    B, Hkv, G, S, D = q.shape
    P, ps, _, _ = k_pages.shape
    NP = table.shape[1]
    grid_spec_cls = pallas_prefetch_grid_spec()
    assert grid_spec_cls is not None, (
        "paged decode kernel needs scalar-prefetch grid specs; gate calls on "
        "ops.paged_decode_supported()")
    kernel = functools.partial(
        _flash_decode_paged_kernel, scale=1.0 / math.sqrt(D), causal=causal,
        window=window, softcap=softcap, n_kv=NP)
    grid_spec = grid_spec_cls(
        num_scalar_prefetch=1,
        grid=(B, Hkv, NP),
        in_specs=[
            pl.BlockSpec((1, S), lambda b, h, j, tab: (b, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, tab: (b, j)),
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j, tab: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j, tab: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, S), lambda b, h, j, tab: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S, D), jnp.float32),
        ],
    )
    out, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        ],
        interpret=interpret,
    )(table, q_positions, kv_positions, q, k_pages, v_pages)
    return out


def _flash_decode_paged_quant_kernel(tab_ref, qp_ref, kp_ref, q_ref, kq_ref,
                                     ks_ref, vq_ref, vs_ref, o_ref, lse_ref,
                                     m_ref, l_ref, acc_ref, *, scale: float,
                                     causal: bool, window: int,
                                     softcap: float, n_kv: int,
                                     head_dim: int):
    del tab_ref  # consumed by the BlockSpec index maps (scalar prefetch)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        _tile_init(m_ref, l_ref, acc_ref)

    kp = kp_ref[0]

    @pl.when(jnp.max(kp) >= 0)          # skip dead pages (trash / unwritten)
    def _update():
        k = _dequant_rows(kq_ref[0, :, 0], ks_ref[0, :, 0], head_dim)
        v = _dequant_rows(vq_ref[0, :, 0], vs_ref[0, :, 0], head_dim)
        _tile_update(q_ref[0, 0].astype(jnp.float32), k, v,
                     qp_ref[0], kp, m_ref, l_ref, acc_ref,
                     scale=scale, causal=causal, window=window,
                     softcap=softcap)

    @pl.when(ki == n_kv - 1)
    def _finalize():
        _tile_finalize(o_ref, lse_ref, m_ref, l_ref, acc_ref)


def flash_decode_paged_quant_fwd(q: jax.Array, k_codes: jax.Array,
                                 k_scale: jax.Array, v_codes: jax.Array,
                                 v_scale: jax.Array, table: jax.Array,
                                 q_positions: jax.Array,
                                 kv_positions: jax.Array, *,
                                 causal: bool = True, window: int = 0,
                                 softcap: float = 0.0,
                                 interpret: bool = True) -> jax.Array:
    """Paged decode kernel over Proteus-quantized pages: int8 / nibble-packed
    int4 code pages + per-row fp32 scale pages, dequantized per page in VMEM
    — the narrow-code HBM saving and the paged allocation saving compose.

    q: (B, Hkv, G, S, D)   code pools: (P, ps, Hkv, Dc) int8
    scale pools: (P, ps, Hkv) fp32   table: (B, NP) int32
    q_positions: (B, S)   kv_positions: (B, NP * ps)
    Returns out (B, Hkv, G, S, D).
    """
    B, Hkv, G, S, D = q.shape
    P, ps, _, Dc = k_codes.shape
    NP = table.shape[1]
    grid_spec_cls = pallas_prefetch_grid_spec()
    assert grid_spec_cls is not None, (
        "paged decode kernel needs scalar-prefetch grid specs; gate calls on "
        "ops.paged_decode_supported()")
    kernel = functools.partial(
        _flash_decode_paged_quant_kernel, scale=1.0 / math.sqrt(D),
        causal=causal, window=window, softcap=softcap, n_kv=NP, head_dim=D)
    grid_spec = grid_spec_cls(
        num_scalar_prefetch=1,
        grid=(B, Hkv, NP),
        in_specs=[
            pl.BlockSpec((1, S), lambda b, h, j, tab: (b, 0)),
            pl.BlockSpec((1, ps), lambda b, h, j, tab: (b, j)),
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j, tab: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, Dc), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1), lambda b, h, j, tab: (tab[b, j], 0, h)),
            pl.BlockSpec((1, ps, 1, Dc), lambda b, h, j, tab: (tab[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1), lambda b, h, j, tab: (tab[b, j], 0, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, S, D), lambda b, h, j, tab: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, 1, G, S), lambda b, h, j, tab: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S), jnp.float32),
            pallas_vmem_scratch((G, S, D), jnp.float32),
        ],
    )
    out, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, G, S, D), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        ],
        interpret=interpret,
    )(table, q_positions, kv_positions, q, k_codes, k_scale, v_codes, v_scale)
    return out


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True) -> jax.Array:
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D). MHA-layout adapter.

    Non-block-multiple S/T are padded to the block multiple (padded kv slots
    carry position -1 and are masked) and the output sliced back.
    """
    BH, S, D = q.shape
    T = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    q_pos = jnp.where(jnp.arange(Sp) < S, jnp.arange(Sp), -1).astype(jnp.int32)
    kv_pos = jnp.where(jnp.arange(Tp) < T, jnp.arange(Tp), -1).astype(jnp.int32)
    out, _ = flash_attention_fwd(
        pad_axis(q, 1, Sp)[:, None, None], pad_axis(k, 1, Tp)[:, None],
        pad_axis(v, 1, Tp)[:, None],
        jnp.tile(q_pos[None], (BH, 1)), jnp.tile(kv_pos[None], (BH, 1)),
        causal=causal, window=window, block_q=bq, block_k=bk,
        interpret=interpret)
    return out[:, 0, 0, :S]
