"""Flash attention Pallas TPU kernel: tiled online softmax.

Processing-using-memory principle on the HBM->VMEM hierarchy: the (bq x bk)
score tile lives only in VMEM; scores never round-trip to HBM (the jnp
chunked path materializes them — this kernel removes the dominant memory-term
contribution found by DAMOV for train/prefill cells).

Grid: (batch*heads, q_blocks, kv_blocks), kv minor => sequential on TPU;
running (m, l, acc) carried in VMEM scratch across kv steps.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from repro.compat import import_pallas, import_pallas_tpu

pl = import_pallas()
pltpu = import_pallas_tpu()  # None when this install lacks TPU pallas

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                                block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q,
                                                                block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - safe_m[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - safe_m))
    l_new = l_prev * alpha + p.sum(axis=1)
    acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_new
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, ...] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       block_q: int = 128, block_k: int = 128,
                       interpret: bool = True) -> jax.Array:
    """q: (BH, S, D), k/v: (BH, T, D) -> (BH, S, D). MHA layout."""
    BH, S, D = q.shape
    _, T, _ = k.shape
    bq = min(block_q, S)
    bk = min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, bq, T, bk)
    nq, nk = S // bq, T // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_k=bk, n_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
