"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True, window: int = 0) -> jax.Array:
    """q: (BH, S, D), k/v: (BH, T, D). Naive quadratic softmax attention."""
    BH, S, D = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
