"""Public wrappers for the flash-attention Pallas kernels.

Model-layout in, model-layout out: q ``(B, S, Hq, D)``, k/v ``(B, T, Hkv, D)``
with ``Hq % Hkv == 0`` (query head ``h`` belongs to kv head ``h // G``). The
wrappers handle the GQA layout transform (no ``jnp.repeat`` of k/v — kv tiles
are shared across the G query heads inside the kernel), default positions,
and pad-to-block-multiple + slice for odd sequence lengths.

The decode wrappers are shape-generic in ``S``: speculative decoding's
draft-verify blocks (``S = k+1`` rows scored in one dispatch) reuse these
exact kernels — position-based causal masking already gives every drafted
row its correct visibility, so verification adds no new kernel variants.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import pallas_prefetch_grid_spec
from repro.kernels.common import pad_axis, pad_positions, use_interpret
from repro.kernels.flash_attention.kernel import (flash_attention_bh,
                                                 flash_attention_fwd,
                                                 flash_decode_fwd,
                                                 flash_decode_paged_fwd,
                                                 flash_decode_paged_quant_fwd,
                                                 flash_decode_quant_fwd)

__all__ = ["flash_attention", "flash_attention_gqa_fwd", "flash_decode",
           "flash_decode_quant", "flash_decode_paged",
           "flash_decode_paged_quant", "paged_decode_supported",
           "flash_attention_bh"]


def _default_positions(B: int, n: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (B, n))


def flash_attention_gqa_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, softcap: float = 0.0,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Pallas forward, any S/T. q: (B, S, Hq, D), k/v: (B, T, Hkv, D).

    Returns (out (B, S, Hq, D), lse (B, Hkv, G, S) f32) — lse is what a
    recompute-based backward needs instead of saved score tiles.
    """
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    q_pos = _default_positions(B, S) if q_positions is None else q_positions
    kv_pos = _default_positions(B, T) if kv_positions is None else kv_positions
    q5 = pad_axis(q, 1, Sp).reshape(B, Sp, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    k4 = pad_axis(k, 1, Tp).transpose(0, 2, 1, 3)
    v4 = pad_axis(v, 1, Tp).transpose(0, 2, 1, 3)
    out5, lse = flash_attention_fwd(
        q5, k4, v4, pad_positions(q_pos.astype(jnp.int32), Sp),
        pad_positions(kv_pos.astype(jnp.int32), Tp),
        causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, interpret=interpret)
    out = out5.transpose(0, 3, 1, 2, 4).reshape(B, Sp, Hq, D)
    return out[:, :S], lse[..., :S]


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "block_q",
                                   "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D) with Hq % Hkv == 0."""
    out, _ = flash_attention_gqa_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 q_positions: jax.Array, kv_positions: jax.Array, *,
                 causal: bool = True, window: int = 0, softcap: float = 0.0,
                 block_k: int = 128,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Decode-step attention against a (ring) KV cache.

    q: (B, S, Hq, D) with small S — 1 for plain decode, or k+1 when the
    serving layer verifies a speculative draft block in one dispatch (each
    drafted row attends causally via its own q_position; no kernel change
    is needed for speculation). k/v: (B, T, Hkv, D) cache, q_positions:
    (B, S) per-sequence absolute positions, kv_positions: (B, T) per-slot
    positions (-1 = empty slot — ring layout and valid-length masking are
    both expressed here).
    """
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    bk = min(block_k, T)
    Tp = -(-T // bk) * bk
    q5 = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    out5 = flash_decode_fwd(
        q5, pad_axis(k, 1, Tp).transpose(0, 2, 1, 3),
        pad_axis(v, 1, Tp).transpose(0, 2, 1, 3),
        q_positions.astype(jnp.int32),
        pad_positions(kv_positions.astype(jnp.int32), Tp),
        causal=causal, window=window, softcap=softcap, block_k=bk,
        interpret=interpret)
    return out5.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)


def paged_decode_supported() -> bool:
    """Whether the paged decode kernels can run on this JAX install.

    The paged kernels resolve the page table inside BlockSpec index maps via
    scalar prefetch, which needs ``pltpu.PrefetchScalarGridSpec`` (absent on
    CPU-only builds without the TPU pallas module). Callers fall back to
    ``paged_gather`` + the dense path when this is False.
    """
    return pallas_prefetch_grid_spec() is not None


def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       table: jax.Array, q_positions: jax.Array,
                       kv_positions: jax.Array, *, causal: bool = True,
                       window: int = 0, softcap: float = 0.0,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Decode-step attention against a paged (block-table) KV cache.

    q: (B, S, Hq, D) with small S; k/v_pages: (P, ps, Hkv, D) shared page
    pool in storage layout (physical page 0 is the trash page); table:
    (B, NP) int32 mapping each slot's logical pages to pool rows;
    q_positions: (B, S); kv_positions: (B, NP * ps) per-slot positions
    (-1 = empty — ring layout, valid length, and dead pages all live here).
    The kernel streams only the pages each slot owns; no dense gather.
    """
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    Hkv = k_pages.shape[2]
    G = Hq // Hkv
    q5 = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    out5 = flash_decode_paged_fwd(
        q5, k_pages, v_pages, table.astype(jnp.int32),
        q_positions.astype(jnp.int32), kv_positions.astype(jnp.int32),
        causal=causal, window=window, softcap=softcap, interpret=interpret)
    return out5.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)


def flash_decode_paged_quant(q: jax.Array, k_codes: jax.Array,
                             k_scale: jax.Array, v_codes: jax.Array,
                             v_scale: jax.Array, table: jax.Array,
                             q_positions: jax.Array,
                             kv_positions: jax.Array, *, causal: bool = True,
                             window: int = 0, softcap: float = 0.0,
                             interpret: Optional[bool] = None) -> jax.Array:
    """Decode-step attention against a Proteus-quantized paged KV cache.

    q: (B, S, Hq, D); code pools: (P, ps, Hkv, Dc) int8 (Dc = D, or D//2
    when nibble-packed int4); scale pools: (P, ps, Hkv) fp32; table /
    positions as in :func:`flash_decode_paged`. Dequantization happens per
    page in VMEM, so the quantized-HBM and paged-allocation savings compose.
    """
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    Hkv = k_codes.shape[2]
    G = Hq // Hkv
    q5 = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    out5 = flash_decode_paged_quant_fwd(
        q5, k_codes, k_scale, v_codes, v_scale, table.astype(jnp.int32),
        q_positions.astype(jnp.int32), kv_positions.astype(jnp.int32),
        causal=causal, window=window, softcap=softcap, interpret=interpret)
    return out5.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)


def flash_decode_quant(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                       v_codes: jax.Array, v_scale: jax.Array,
                       q_positions: jax.Array, kv_positions: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       softcap: float = 0.0, block_k: int = 128,
                       interpret: Optional[bool] = None) -> jax.Array:
    """Decode-step attention against a Proteus-quantized (ring) KV cache.

    q: (B, S, Hq, D); codes: (B, T, Hkv, Dc) int8 (Dc = D, or D//2 when
    nibble-packed int4); scales: (B, T, Hkv) fp32 per (slot, kv head) row;
    positions as in :func:`flash_decode`. Dequantization happens inside the
    kernel, per tile in VMEM — HBM reads only the narrow codes + scales.
    """
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k_codes.shape
    G = Hq // Hkv
    bk = min(block_k, T)
    Tp = -(-T // bk) * bk
    q5 = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    out5 = flash_decode_quant_fwd(
        q5,
        pad_axis(k_codes, 1, Tp).transpose(0, 2, 1, 3),
        pad_axis(k_scale, 1, Tp).transpose(0, 2, 1),
        pad_axis(v_codes, 1, Tp).transpose(0, 2, 1, 3),
        pad_axis(v_scale, 1, Tp).transpose(0, 2, 1),
        q_positions.astype(jnp.int32),
        pad_positions(kv_positions.astype(jnp.int32), Tp),
        causal=causal, window=window, softcap=softcap, block_k=bk,
        interpret=interpret)
    return out5.transpose(0, 3, 1, 2, 4).reshape(B, S, Hq, D)
