"""jit'd public wrapper: GQA-aware flash attention."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.flash_attention.kernel import flash_attention_bh


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, S, Hq, D); k/v: (B, T, Hkv, D) with Hq % Hkv == 0."""
    if interpret is None:
        interpret = use_interpret()
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    qb = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kb = k.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    vb = v.transpose(0, 2, 1, 3).reshape(B * Hq, T, D)
    ob = flash_attention_bh(qb, kb, vb, causal=causal, window=window,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return ob.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
