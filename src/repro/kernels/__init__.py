"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper) and ref.py (pure-jnp oracle); validated with
interpret=True on CPU, targeted at TPU.
"""
from repro.kernels.common import use_interpret

__all__ = ["use_interpret"]
