"""Proteus-on-TPU: data-aware dynamic precision runtime (thesis chapter 6).

The thesis' three mechanisms and their TPU-native forms:

  1. *Narrow values* -> per-block dynamic-range detection on tensors
     (``required_bits_*``, the DBPE analogue) and block-scaled int8/int4
     quantization whose cost is paid only over consequential bits.
  2. *SALP latency hiding* -> bucketed collectives overlapped with the
     producing computation (``bucketize``), and pod-local-first hierarchical
     reduction so the slow inter-pod hop carries one pre-reduced, quantized
     operand (``cross_pod_psum``).
  3. *uProgram select unit* -> a roofline cost model (``CostModel``) that
     transparently picks {bf16, int8, int4} x {algorithm} per tensor from
     observed statistics (thesis Fig 6.7).

The RBR carry-free representation has no MXU analogue; its role — bounding
error/carry propagation and making latency magnitude-independent — is played
by fixed-size per-block scaling (see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import axis_size, tree_map
from repro.kernels.common import pack_int4, unpack_int4  # noqa: F401  (re-export)


# ---------------------------------------------------------------------------
# Narrow-value detection (DBPE analogue)
# ---------------------------------------------------------------------------
def block_maxabs(x: jax.Array, block: int = 256) -> jax.Array:
    """Per-block max |x| over the flattened tensor (padded with 0)."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return jnp.abs(flat.reshape(-1, block)).max(axis=1)


def block_stats(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    """(maxabs, meanabs) per block; padding excluded from the mean."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = jnp.abs(flat.reshape(-1, block))
    counts = jnp.clip(n - jnp.arange(blocks.shape[0]) * block, 1, block)
    return blocks.max(axis=1), blocks.sum(axis=1) / counts


def block_crest(x: jax.Array, block: int = 256) -> jax.Array:
    """Worst per-block crest factor max|x| / mean|x| (>= 1).

    The data-aware narrow-value signal: uniform-magnitude blocks have crest
    ~1 (block scaling absorbs the whole range, every code bit is
    consequential), spiky blocks have large crest (small elements see large
    relative error under the shared block scale)."""
    maxabs, meanabs = block_stats(x, block)
    crest = jnp.where(maxabs > 0, maxabs / jnp.maximum(meanabs, 1e-30), 1.0)
    return jnp.max(crest)


def required_bits_int(x: jax.Array) -> jax.Array:
    """Exact Proteus narrow-value width for integer data: bits to represent
    the widest element in two's complement (sign included)."""
    m = jnp.max(jnp.abs(x.astype(jnp.int64)))
    # bits = ceil(log2(m+1)) + 1 sign bit; m=0 -> 1 bit
    return jnp.where(m == 0, 1, jnp.ceil(jnp.log2(m.astype(jnp.float64) + 1.0))
                     .astype(jnp.int32) + 1)


def required_bits_float(x: jax.Array, block: int = 256,
                        rtol: float = 1e-2) -> jax.Array:
    """Bits so per-element quantization error <= rtol * block mean |x|.

    Data-aware (uses ``block_stats`` of the actual tensor): the block-scaled
    error is scale/2 = maxabs / (2^(b-1)-1) / 2, so relative to the typical
    element magnitude it is amplified by the block crest factor
    c = maxabs/meanabs:

        maxabs / (2^(b-1)-1) / 2 <= rtol * meanabs  ->  2^(b-1) >= c/(2 rtol) + 1

    Uniform-magnitude blocks (c ~ 1) admit the narrowest representation —
    the thesis' narrow-value detection; spiky blocks need more bits.
    """
    crest = block_crest(x, block)
    need = jnp.ceil(jnp.log2(crest / (2.0 * rtol) + 1.0)) + 1.0
    return jnp.maximum(need, 2.0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Block-scaled quantization (the RBR-replacement representation)
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    values: jax.Array          # int8 codes (int4 stored in int8 range [-8,7])
    scale: jax.Array           # (nblocks,) fp32
    bits: int
    block: int
    shape: Tuple[int, ...]
    dtype: Any

    def tree_flatten(self):
        return (self.values, self.scale), (self.bits, self.block, self.shape,
                                           self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    @property
    def nbytes_payload(self) -> int:
        n = int(np.prod(self.shape))
        return (n * self.bits + 7) // 8 + self.scale.size * 4


def quantize(x: jax.Array, bits: int = 8, block: int = 256) -> QTensor:
    """Symmetric per-block quantization. bits in {4, 8}."""
    assert bits in (4, 8), bits
    qmax = float(2 ** (bits - 1) - 1)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    maxabs = jnp.abs(blocks).max(axis=1)
    scale = jnp.where(maxabs == 0, 1.0, maxabs / qmax)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -qmax - 1, qmax)
    return QTensor(q.astype(jnp.int8), scale, bits, block, shape, dtype)


def dequantize(qt: QTensor) -> jax.Array:
    blocks = qt.values.astype(jnp.float32) * qt.scale[:, None]
    flat = blocks.reshape(-1)[: int(np.prod(qt.shape))]
    return flat.reshape(qt.shape).astype(qt.dtype)


# Canonical int4 nibble pack/unpack lives in repro.kernels.common (pure jnp,
# compat-clean) and is re-exported from this module's import block above so
# existing proteus callers keep working.


# ---------------------------------------------------------------------------
# Quantized collectives (inside shard_map)
# ---------------------------------------------------------------------------
def proteus_psum(x: jax.Array, axis_name: Any, *, bits: int = 8,
                 block: int = 256) -> jax.Array:
    """Quantized all-reduce: shared per-block scale (one small fp32 psum-max),
    int payload summed in int32, dequantized mean-preserving.

    Exact-sum error <= n_devices * scale/2 per element; scale is the global
    per-block max so codes cannot overflow int32 for n <= 2^23 devices.
    """
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    qmax = float(2 ** (bits - 1) - 1)
    local_max = jnp.abs(blocks).max(axis=1)
    global_max = jax.lax.pmax(local_max, axis_name)        # tiny fp32 collective
    scale = jnp.where(global_max == 0, 1.0, global_max / qmax)
    # Narrow-wire ring reduction: each of the n-1 hops carries int8 codes
    # (point-to-point ppermute; XLA's SPMD partitioner rejects sub-int32
    # psum payloads under partial-manual meshes), accumulating locally in
    # int32. Wire bytes/device = (n-1) * n_elems * 1B — 4x narrower than
    # an fp32 ring all-reduce, 2x narrower than bf16. The hops run inside a
    # fori_loop (static perm, carried (buf, acc)) so HLO size and trace time
    # are O(1) in device count, not O(n_dev).
    n_dev = axis_size(axis_name)
    q8 = jnp.round(blocks / scale[:, None]).astype(jnp.int8)
    perm = tuple((i, (i + 1) % n_dev) for i in range(n_dev))

    def hop(_, carry):
        buf, acc = carry
        buf = jax.lax.ppermute(buf, axis_name, perm)
        return buf, acc + buf.astype(jnp.int32)

    _, acc = jax.lax.fori_loop(0, n_dev - 1, hop, (q8, q8.astype(jnp.int32)))
    out = (acc.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return out.reshape(shape).astype(dtype)


def cross_pod_psum(tree: Any, pod_axis: str = "pod", *, bits: int = 8,
                   block: int = 256, mean: bool = False,
                   n_pods: Optional[int] = None) -> Any:
    """Hierarchical + quantized reduction for gradient trees across pods."""

    def red(g):
        y = proteus_psum(g, pod_axis, bits=bits, block=block)
        if mean and n_pods:
            y = y / n_pods
        return y

    return tree_map(red, tree)


def bucketize(tree: Any, bucket_bytes: int = 4 << 20) -> List[List[Tuple]]:
    """Split a gradient pytree into collective buckets (overlap units).

    Returns buckets of (path, leaf) so callers can issue one collective per
    bucket — XLA's latency-hiding scheduler then overlaps them with the
    producing backward computation (the SALP analogue).
    """
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    buckets: List[List[Tuple]] = [[]]
    acc = 0
    for path, leaf in leaves:
        sz = leaf.size * leaf.dtype.itemsize
        if acc + sz > bucket_bytes and buckets[-1]:
            buckets.append([])
            acc = 0
        buckets[-1].append((path, leaf))
        acc += sz
    return buckets


# ---------------------------------------------------------------------------
# uProgram select unit: roofline cost model (thesis Fig 6.7)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Representation:
    name: str            # "bf16" | "int8" | "int4"
    bits: int
    rel_err: float       # worst-case per-element relative error vs block max


REPRESENTATIONS = (
    Representation("bf16", 16, 2 ** -8),
    Representation("int8", 8, 0.5 / 127.0),
    Representation("int4", 4, 0.5 / 7.0),
)


@dataclass
class CostModel:
    """Pick the cheapest representation meeting an error budget.

    Latency model for a collective of n fp32 elements at width b over a link
    of ``link_bw``: t = n*b/8 / link_bw + fixed quant overhead n/vpu_rate.
    Mirrors Proteus' (latency-oriented vs throughput-oriented) uProgram
    selection: when payloads are small, quantization overhead dominates and
    wider formats win; when large, narrower wins.
    """

    link_bw: float = 50e9
    vpu_rate: float = 4e12     # elementwise ops/s (quantize/dequantize cost)

    def latency(self, n_elems: int, rep: Representation) -> float:
        t_wire = n_elems * rep.bits / 8.0 / self.link_bw
        t_quant = 0.0 if rep.name == "bf16" else 3.0 * n_elems / self.vpu_rate
        return t_wire + t_quant

    def select(self, n_elems: int, err_budget: float) -> Representation:
        feasible = [r for r in REPRESENTATIONS if r.rel_err <= err_budget]
        if not feasible:
            feasible = [REPRESENTATIONS[0]]
        return min(feasible, key=lambda r: self.latency(n_elems, r))

    def select_for_tensor(self, x: jax.Array, block: int = 256,
                          err_budget: float = 5e-3) -> Representation:
        """Data-aware selection from observed block statistics.

        A representation's worst per-element error relative to typical
        magnitudes is rel_err * crest (crest = worst block max|x|/mean|x|):
        block scaling absorbs the range of uniform-magnitude blocks (crest
        ~1, narrow formats are safe) while spiky tensors force wider ones.
        """
        crest = float(block_crest(x, block))
        feasible = [r for r in REPRESENTATIONS
                    if r.rel_err * crest <= err_budget]
        if not feasible:
            feasible = [REPRESENTATIONS[0]]
        return min(feasible, key=lambda r: self.latency(x.size, r))


# ---------------------------------------------------------------------------
# Gradient compression wrapper for the train step
# ---------------------------------------------------------------------------
def maybe_compress_grads(grads: Any, enabled: bool, pod_axis: Optional[str],
                         bits: int = 8, block: int = 256,
                         n_pods: Optional[int] = None) -> Any:
    """Apply quantized cross-pod reduction when enabled (shard_map context)."""
    if not enabled or pod_axis is None:
        return grads
    return cross_pod_psum(grads, pod_axis, bits=bits, block=block,
                          mean=True, n_pods=n_pods)
