"""DAMOV-on-TPU: compiled-artifact workload characterization (thesis ch. 4).

The thesis' three-step methodology, re-targeted from CPU simulators to XLA
compiled artifacts:

  Step 1 — *bound identification*: a full HLO cost analysis (FLOPs, HBM
           traffic, collective traffic) of the partitioned per-device module.
           Unlike ``compiled.cost_analysis()``, this analyzer multiplies
           while-loop bodies by their trip counts (scan-over-layers and
           chunked attention would otherwise be undercounted by 10-100x).
  Step 2 — *locality clustering*: arithmetic intensity + useful-FLOPs ratio
           (MODEL_FLOPS / HLO_FLOPS, the remat/redundancy detector).
  Step 3 — *bottleneck classification* into the DAMOV-class analogues
           (MXU / MEM_BW / LAT / ICI_CONT — see DESIGN.md §2).

The output drives the MIMDRAM planner and the Proteus cost model: this is the
"characterize before you optimize" layer.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e-class target; per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_LINK_BW = 50e9              # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s([a-z][a-z0-9_\-]*)\((.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> Tuple[Tuple[int, ...], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return (), ""
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return dims, m.group(1)


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attrs (raw tail)


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    table: Dict[str, str] = field(default_factory=dict)  # name -> type_str


@dataclass
class HloStats:
    flops: float = 0.0
    bytes: float = 0.0
    coll_operand_bytes: float = 0.0
    coll_wire_bytes: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    n_collectives: int = 0
    n_dots: int = 0
    trip_counts: List[int] = field(default_factory=list)

    def merged(self, other: "HloStats", mult: float = 1.0) -> "HloStats":
        out = HloStats(
            self.flops + mult * other.flops,
            self.bytes + mult * other.bytes,
            self.coll_operand_bytes + mult * other.coll_operand_bytes,
            self.coll_wire_bytes + mult * other.coll_wire_bytes,
            dict(self.by_kind),
            dict(self.bytes_by_op),
            self.n_collectives + other.n_collectives,
            self.n_dots + other.n_dots,
            self.trip_counts + other.trip_counts,
        )
        for k, v in other.by_kind.items():
            out.by_kind[k] = out.by_kind.get(k, 0.0) + mult * v
        for k, v in other.bytes_by_op.items():
            out.bytes_by_op[k] = out.bytes_by_op.get(k, 0.0) + mult * v
        return out


# ---------------------------------------------------------------------------
# Parsing
# ---------------------------------------------------------------------------
def parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(2))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        inst = Instr(name, type_str.strip(), opcode, rest)
        cur.instrs.append(inst)
        cur.table[name] = inst.type_str
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _operand_names(rest: str) -> List[str]:
    """Names referenced before the closing paren of the operand list."""
    depth = 1
    out = []
    buf = ""
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf += ch
    return re.findall(r"%([\w.\-]+)", buf)


def _attr(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-{}]+)", rest)
    return m.group(1) if m else None


def _group_size(rest: str, default: int = 1) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return default


def _dot_flops(inst: Instr, table: Dict[str, str]) -> float:
    dims, _ = _shape_dims(inst.type_str)
    out_elems = 1
    for d in dims:
        out_elems *= d
    ops = _operand_names(inst.rest)
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", inst.rest)
    if m and ops:
        lhs_dims, _ = _shape_dims(table.get(ops[0], ""))
        for ix in (m.group(1).split(",") if m.group(1) else []):
            i = int(ix)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * out_elems * contract


def _trip_count(inst: Instr, cond: Optional[Computation]) -> int:
    # XLA annotates known trip counts on the while instruction itself.
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', inst.rest)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    # fallback: largest integer constant in the condition computation
    best = 1
    for ci in cond.instrs:
        if ci.opcode == "constant":
            mm = re.match(r"([0-9]+)", ci.rest)
            if mm and _shape_dims(ci.type_str)[1] in ("s32", "u32", "s64", "u64"):
                best = max(best, int(mm.group(1)))
    return best


_SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id",
}

_SLICY = {"dynamic-slice", "slice", "gather"}

# materialization boundaries: ops whose inputs/outputs hit HBM on TPU
_BOUNDARY_BYTES_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate",
    "transpose", "sort", "fusion", "copy", "pad", "reverse", "cumsum",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "reduce-scatter-start", "collective-permute-start", "custom-call",
}


def _fusion_operand_bytes(inst: Instr, comp: Computation,
                          comps: Dict[str, Computation]) -> int:
    """Effective operand traffic of a fusion: parameters whose only fused uses
    are (dynamic-)slice/gather count at consumed size, not full size."""
    ops_ = _operand_names(inst.rest)
    called_name = _attr(inst.rest, "calls")
    called = comps.get(called_name) if called_name else None
    if called is None:
        return sum(_shape_bytes(comp.table.get(o, "")) for o in ops_)
    # map parameter index -> uses inside the fused computation
    param_names: Dict[int, str] = {}
    for ci in called.instrs:
        if ci.opcode == "parameter":
            m = re.match(r"(\d+)", ci.rest)
            if m:
                param_names[int(m.group(1))] = ci.name
    total = 0
    for i, o in enumerate(ops_):
        full = _shape_bytes(comp.table.get(o, ""))
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        uses = [ci for ci in called.instrs
                if pname in _operand_names(ci.rest)]
        if uses and all(u.opcode in _SLICY for u in uses):
            total += sum(_shape_bytes(u.type_str) for u in uses)
        else:
            total += full
    return total

_ELEMWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "exponential", "tanh",
    "log", "rsqrt", "sqrt", "maximum", "minimum", "negate", "abs", "floor",
    "select", "compare", "convert", "cosine", "sine", "logistic",
    "exponential-minus-one", "log-plus-one",
}


def analyze_computation(comp: Computation, comps: Dict[str, Computation],
                        cache: Dict[str, HloStats], *, top_level: bool,
                        ) -> HloStats:
    key = comp.name + ("#t" if top_level else "#f")
    if key in cache:
        return cache[key]
    st = HloStats()
    for inst in comp.instrs:
        op = inst.opcode
        res_bytes = _shape_bytes(inst.type_str)
        # ---- flops ----
        if op == "dot":
            st.flops += _dot_flops(inst, comp.table)
            st.n_dots += 1
        elif op == "convolution":
            st.flops += 2.0 * res_bytes  # rough; models avoid conv HLO
        elif op in _ELEMWISE_FLOP_OPS:
            dims, dt = _shape_dims(inst.type_str)
            n = 1
            for d in dims:
                n *= d
            st.flops += n
        # ---- collectives ----
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVE_OPS:
            g = _group_size(inst.rest, 1)
            if base == "all-gather":
                operand = res_bytes / max(g, 1)
                wire = operand * max(g - 1, 0)
            elif base == "reduce-scatter":
                operand = res_bytes * g
                wire = res_bytes * max(g - 1, 0)
            elif base == "all-reduce":
                operand = res_bytes
                wire = 2.0 * operand * (max(g - 1, 0) / max(g, 1))
            elif base in ("all-to-all", "ragged-all-to-all"):
                operand = res_bytes
                wire = operand * (max(g - 1, 0) / max(g, 1))
            else:  # collective-permute / broadcast
                operand = res_bytes
                wire = operand
            st.coll_operand_bytes += operand
            st.coll_wire_bytes += wire
            st.by_kind[base] = st.by_kind.get(base, 0.0) + operand
            st.n_collectives += 1
        # ---- bytes: HBM traffic at materialization boundaries only.
        # Elementwise / broadcast / select chains fuse on TPU, so they carry
        # no HBM cost; dots, reduces, slices, scatters, concats, copies and
        # fusions are where buffers hit HBM.
        if (top_level and op in _BOUNDARY_BYTES_OPS
                and not op.endswith("-done")):
            if op in _SLICY:
                # slices/gathers touch only what they produce, not the source
                opb = res_bytes
            elif op == "dynamic-update-slice":
                # read + write the update region only (in-place on TPU)
                ops_ = _operand_names(inst.rest)
                upd = _shape_bytes(comp.table.get(ops_[1], "")) if len(ops_) > 1 \
                    else res_bytes
                opb = 2 * upd - res_bytes  # res added below; net = 2*update
            elif op == "fusion":
                opb = _fusion_operand_bytes(inst, comp, comps)
            else:
                opb = sum(_shape_bytes(comp.table.get(o, ""))
                          for o in _operand_names(inst.rest))
            st.bytes += res_bytes + opb
            st.bytes_by_op[op] = st.bytes_by_op.get(op, 0.0) + res_bytes + opb
        # ---- control flow ----
        if op == "while":
            body_n = _attr(inst.rest, "body")
            cond_n = _attr(inst.rest, "condition")
            trips = _trip_count(inst, comps.get(cond_n) if cond_n else None)
            if body_n and body_n in comps:
                sub = analyze_computation(comps[body_n], comps, cache,
                                          top_level=top_level)
                st = st.merged(sub, float(trips))
                st.trip_counts.append(trips)
        elif op == "fusion":
            called = _attr(inst.rest, "calls")
            if called and called in comps:
                sub = analyze_computation(comps[called], comps, cache,
                                          top_level=False)
                st = st.merged(sub, 1.0)
        elif op == "call":
            called = _attr(inst.rest, "to_apply")
            if called and called in comps:
                sub = analyze_computation(comps[called], comps, cache,
                                          top_level=top_level)
                st = st.merged(sub, 1.0)
        elif op == "conditional":
            branches = re.findall(r"%([\w.\-]+)", inst.rest.split("),", 1)[-1])
            subs = [analyze_computation(comps[b], comps, cache, top_level=top_level)
                    for b in branches if b in comps]
            if subs:
                biggest = max(subs, key=lambda s: s.flops)
                st = st.merged(biggest, 1.0)
    cache[key] = st
    return st


def analyze_hlo(text: str) -> HloStats:
    """Full-module analysis of a partitioned (per-device) HLO module."""
    comps = parse_computations(text)
    entry = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m and m.group(1):
            entry = m.group(2)
            break
    if entry is None:
        # fall back: the computation named main-ish or the largest
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    cache: Dict[str, HloStats] = {}
    return analyze_computation(comps[entry], comps, cache, top_level=True)


# ---------------------------------------------------------------------------
# Roofline (step 1 output -> step 3 classification)
# ---------------------------------------------------------------------------
@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bottleneck_class: str
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    coll_operand_bytes: float   # per device
    coll_wire_bytes: float
    model_flops: float          # global useful FLOPs (6ND / 2ND)
    useful_ratio: float
    arithmetic_intensity: float
    step_time_s: float
    roofline_fraction: float    # useful FLOPs rate / peak
    by_kind: Dict[str, float] = field(default_factory=dict)
    notes: str = ""

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def classify(compute_s: float, memory_s: float, collective_s: float,
             mode: str) -> Tuple[str, str]:
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    if dominant == "collective":
        clazz = "ICI_CONT (2a)"
    elif dominant == "compute":
        clazz = "MXU (2c)"
    else:
        clazz = "LAT (1b)" if mode == "decode" else "MEM_BW (1a)"
    return dominant, clazz


def make_roofline(arch: str, shape_name: str, mode: str, mesh_desc: str,
                  n_chips: int, stats: HloStats, model_flops: float,
                  notes: str = "") -> Roofline:
    compute_s = stats.flops / PEAK_FLOPS_BF16
    memory_s = stats.bytes / HBM_BW
    collective_s = stats.coll_wire_bytes / ICI_LINK_BW
    dominant, clazz = classify(compute_s, memory_s, collective_s, mode)
    step = max(compute_s, memory_s, collective_s)
    useful = model_flops / max(stats.flops * n_chips, 1.0)
    frac = (model_flops / max(step, 1e-12)) / (n_chips * PEAK_FLOPS_BF16)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_desc, n_chips=n_chips,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, bottleneck_class=clazz,
        hlo_flops=stats.flops, hlo_bytes=stats.bytes,
        coll_operand_bytes=stats.coll_operand_bytes,
        coll_wire_bytes=stats.coll_wire_bytes,
        model_flops=model_flops, useful_ratio=useful,
        arithmetic_intensity=stats.flops / max(stats.bytes, 1.0),
        step_time_s=step, roofline_fraction=frac,
        by_kind=dict(stats.by_kind), notes=notes,
    )


def model_flops_for(cfg, shape, n_active_params: int) -> float:
    """Standard useful-FLOPs metric: 6*N*D train, 2*N*D forward-only."""
    if shape.mode == "train":
        per_tok = 6.0 * n_active_params
        toks = shape.global_batch * shape.seq_len
    elif shape.mode == "prefill":
        per_tok = 2.0 * n_active_params
        toks = shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        per_tok = 2.0 * n_active_params
        toks = shape.global_batch
    return per_tok * toks


# ---------------------------------------------------------------------------
# Step 2/3 reporting
# ---------------------------------------------------------------------------
def what_would_help(r: Roofline) -> str:
    if r.dominant == "collective":
        big = max(r.by_kind, key=r.by_kind.get) if r.by_kind else "?"
        return (f"dominant collective is {big}: quantize payloads (Proteus int8 "
                f"halves the term) or re-map axes to keep that operand pod-local")
    if r.dominant == "compute":
        if r.useful_ratio < 0.5:
            return ("compute-bound but useful-ratio "
                    f"{r.useful_ratio:.2f}: cut redundant/replicated compute "
                    "(head padding for TP, causal block-skip, less remat)")
        return "near-roofline: only algorithmic change (sparsity, quantized matmul) helps"
    return ("memory-bound: fuse/quantize to cut HBM traffic, enlarge per-chip "
            "batch, or shard the dominant resident tensor (KV cache) further")


def render_table(rows: List[Roofline]) -> str:
    hdr = ("| arch | shape | mesh | compute_s | memory_s | collective_s | "
           "dominant | class | MF/HF | roofline_frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.3e} | "
            f"{r.memory_s:.3e} | {r.collective_s:.3e} | {r.dominant} | "
            f"{r.bottleneck_class} | {r.useful_ratio:.3f} | "
            f"{r.roofline_fraction:.3f} |")
    return "\n".join(lines)
