"""DaPPA-on-TPU: data-parallel pattern programming framework (thesis ch. 7).

The thesis' five primary data-parallel pattern primitives — ``map``, ``zip``,
``reduce``, ``window``, ``filter`` — composed through a dataflow interface
and lowered by *template-based compilation* onto the TPU mesh:

    UPMEM DaPPA                      ->  here
    -----------------------------------------------------------------
    CPU->DPU input transfer          ->  input sharding (data axis)
    per-DPU kernel template          ->  per-shard jnp template
    inter-DPU merge via host         ->  jax.lax collectives (psum/...)
    window halo via host round-trip  ->  ppermute halo exchange
    DPU->CPU gather                  ->  out_specs / all_gather

Users never write PartitionSpecs or collectives; ``compile_pipeline``
assembles the templates into one jit'd SPMD program (thesis Fig 7.3).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import axis_size, shard_map


# ---------------------------------------------------------------------------
# Dataflow graph
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Stream:
    """A lazy distributed 1-D data stream (leading axis = data axis)."""

    kind: str                      # input | map | zip | window | filter
    parents: Tuple["Stream", ...] = ()
    fn: Optional[Callable] = None
    name: str = ""
    wsize: int = 0
    fill: Any = 0

    # -- pattern API (the five DaPPA primitives) -----------------------------
    def map(self, fn: Callable) -> "Stream":
        return Stream("map", (self,), fn)

    def zip(self, *others: "Stream") -> "Stream":
        return Stream("zip", (self,) + others)

    def window(self, w: int, fn: Callable) -> "Stream":
        """Sliding window of w elements -> fn over the window axis (last)."""
        return Stream("window", (self,), fn, wsize=w)

    def filter(self, pred: Callable, fill: Any = 0) -> "Stream":
        return Stream("filter", (self,), pred, fill=fill)

    def reduce(self, kind: str = "sum") -> "Reduction":
        return Reduction(self, kind)


@dataclass(frozen=True)
class Reduction:
    stream: Stream
    kind: str                      # sum | max | min | mean | count


def input_stream(name: str) -> Stream:
    return Stream("input", (), None, name=name)


# ---------------------------------------------------------------------------
# Template-based lowering
# ---------------------------------------------------------------------------
@dataclass
class _Ctx:
    env: Dict[str, jax.Array]
    axis: Optional[str]            # inside shard_map: data axis name
    n_shards: int
    cache: Dict[int, Tuple[jax.Array, Optional[jax.Array]]] = field(
        default_factory=dict)


def _halo_from_next(x: jax.Array, w: int, axis: str) -> jax.Array:
    """Fetch the first w elements of the next shard (ring ppermute)."""
    n = axis_size(axis)
    edge = x[:w]
    perm = [(i, (i - 1) % n) for i in range(n)]     # shard i sends to i-1
    return jax.lax.ppermute(edge, axis, perm)


def _eval(s: Stream, ctx: _Ctx) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Returns (values, validity_mask or None)."""
    key = id(s)
    if key in ctx.cache:
        return ctx.cache[key]
    if s.kind == "input":
        out = (ctx.env[s.name], None)
    elif s.kind == "map":
        v, m = _eval(s.parents[0], ctx)
        out = (s.fn(v), m)
    elif s.kind == "zip":
        vs, ms = zip(*[_eval(p, ctx) for p in s.parents])
        mask = None
        for m in ms:
            if m is not None:
                mask = m if mask is None else (mask & m)
        out = (jnp.stack(vs, axis=-1) if all(v.ndim == vs[0].ndim for v in vs)
               else tuple(vs), mask)
    elif s.kind == "filter":
        v, m = _eval(s.parents[0], ctx)
        keep = s.fn(v).astype(bool)
        if keep.ndim > 1:
            keep = keep.reshape(keep.shape[0], -1).all(-1)
        mask = keep if m is None else (m & keep)
        out = (v, mask)
    elif s.kind == "window":
        v, m = _eval(s.parents[0], ctx)
        w = s.wsize
        n_local = v.shape[0]
        if ctx.axis is not None:
            halo = _halo_from_next(v, w - 1, ctx.axis)
            ext = jnp.concatenate([v, halo], axis=0)
            shard_ix = jax.lax.axis_index(ctx.axis)
            gpos = shard_ix * n_local + jnp.arange(n_local)
            n_total = n_local * ctx.n_shards
        else:
            pad = jnp.zeros((w - 1,) + v.shape[1:], v.dtype)
            ext = jnp.concatenate([v, pad], axis=0)
            gpos = jnp.arange(n_local)
            n_total = n_local
        # one gather with a precomputed (n_local, w) index matrix instead of
        # w materialized shifted copies (w slice+stack HLO ops)
        idx = (jnp.arange(n_local)[:, None]
               + jnp.arange(w)[None, :])                  # (n_local, w)
        wins = jnp.moveaxis(ext[idx], 1, -1)
        valid = gpos <= (n_total - w)
        mask = valid if m is None else (m & valid)
        out = (s.fn(wins), mask)
    else:
        raise ValueError(s.kind)
    ctx.cache[key] = out
    return out


_REDUCE_INIT = {"sum": 0.0, "max": -jnp.inf, "min": jnp.inf}


def _eval_reduction(r: Reduction, ctx: _Ctx) -> jax.Array:
    v, m = _eval(r.stream, ctx)
    vf = v.astype(jnp.float32)
    if r.kind == "count":
        local = (m.astype(jnp.float32).sum() if m is not None
                 else jnp.float32(v.shape[0]))
    elif r.kind in ("sum", "mean"):
        if m is not None:
            vf = jnp.where(_bmask(m, vf), vf, 0.0)
        local = vf.sum()
    elif r.kind == "max":
        if m is not None:
            vf = jnp.where(_bmask(m, vf), vf, -jnp.inf)
        local = vf.max()
    elif r.kind == "min":
        if m is not None:
            vf = jnp.where(_bmask(m, vf), vf, jnp.inf)
        local = vf.min()
    else:
        raise ValueError(r.kind)
    if ctx.axis is not None:
        if r.kind in ("sum", "mean", "count"):
            local = jax.lax.psum(local, ctx.axis)
        elif r.kind == "max":
            local = jax.lax.pmax(local, ctx.axis)
        elif r.kind == "min":
            local = jax.lax.pmin(local, ctx.axis)
    if r.kind == "mean":
        cnt = _eval_reduction(Reduction(r.stream, "count"), ctx)
        return local / jnp.maximum(cnt, 1.0)
    return local


def _bmask(m: jax.Array, v: jax.Array) -> jax.Array:
    while m.ndim < v.ndim:
        m = m[..., None]
    return m


# ---------------------------------------------------------------------------
# Pipeline compiler
# ---------------------------------------------------------------------------
def compile_pipeline(outputs: Any, mesh: Optional[Mesh] = None,
                     data_axis: str = "data") -> Callable:
    """Lower a dataflow of patterns into one jit'd SPMD function.

    ``outputs``: a Reduction / Stream or pytree of them. Returns
    f(**inputs) -> matching pytree of results. With a mesh, inputs are
    sharded on their leading dim over ``data_axis``; reductions come back
    replicated, streams sharded.
    """
    leaves, treedef = jax.tree_util.tree_flatten(
        outputs, is_leaf=lambda x: isinstance(x, (Stream, Reduction)))
    names = _collect_inputs(leaves)

    def run_local(env: Dict[str, jax.Array], axis: Optional[str], n: int):
        ctx = _Ctx(env, axis, n)
        res = []
        for leaf in leaves:
            if isinstance(leaf, Reduction):
                res.append(_eval_reduction(leaf, ctx))
            else:
                v, m = _eval(leaf, ctx)
                res.append(v if m is None else jnp.where(_bmask(m, v), v,
                                                         leaf.fill))
        return tuple(res)

    if mesh is None:
        def fn(**inputs):
            out = run_local(inputs, None, 1)
            return jax.tree_util.tree_unflatten(treedef, out)
        return jax.jit(fn)

    n_shards = mesh.shape[data_axis]
    in_specs = {k: P(data_axis) for k in names}
    out_specs = tuple(
        P() if isinstance(l, Reduction) else P(data_axis) for l in leaves)

    def sharded(env):
        return run_local(env, data_axis, n_shards)

    smapped = shard_map(
        sharded, mesh=mesh,
        in_specs=(in_specs,), out_specs=out_specs,
        check_vma=False)

    def fn(**inputs):
        out = smapped(inputs)
        return jax.tree_util.tree_unflatten(treedef, out)

    return jax.jit(fn)


def _collect_inputs(leaves: Sequence[Any]) -> List[str]:
    names: List[str] = []

    def walk(s: Stream):
        if s.kind == "input" and s.name not in names:
            names.append(s.name)
        for p in s.parents:
            walk(p)

    for leaf in leaves:
        walk(leaf.stream if isinstance(leaf, Reduction) else leaf)
    return names


# convenience namespace mirroring the thesis' API table
def map_(s: Stream, fn: Callable) -> Stream:
    return s.map(fn)


def zip_(*streams: Stream) -> Stream:
    return streams[0].zip(*streams[1:])
