"""The paper's four contributions, TPU-native (see DESIGN.md):

- :mod:`repro.core.damov`   -- compiled-artifact workload characterization
- :mod:`repro.core.mimdram` -- fine-grained mesh-resource allocation (planner)
- :mod:`repro.core.proteus` -- data-aware dynamic-precision runtime
- :mod:`repro.core.dappa`   -- data-parallel pattern programming framework
"""
from repro.core import damov, dappa, mimdram, proteus

__all__ = ["damov", "dappa", "mimdram", "proteus"]
