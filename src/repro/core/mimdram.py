"""MIMDRAM-on-TPU: fine-grained resource allocation for a wide SIMD substrate.

Thesis chapter 5 adaptation (see DESIGN.md §2.2). The DRAM row = the device
mesh; DRAM mats = mesh segments. This module is the *sharding planner*: it
plays the role of MIMDRAM's compiler passes + OS data-mapping support:

  * discovers each tensor dimension's available parallelism (the thesis'
    "vectorization factor", VF),
  * allocates only the needed mesh resources to each logical axis
    (logical-axis rules -> PartitionSpec), including MIMD segments for MoE
    experts (different experts = different PUD ops executing concurrently),
  * reports *segment utilization* — the thesis' SIMD-utilization metric
    (Fig 5.13) — for every (arch x shape x mesh) cell,
  * provides native cross-segment vector reduction (hierarchical, pod-local
    first), MIMDRAM's reduction-tree analogue.

Everything here is data-mapping policy; mechanism lives in XLA GSPMD.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import in_manual_context
from repro.configs.base import ModelConfig, ShapeConfig

# ---------------------------------------------------------------------------
# Logical axis vocabulary
# ---------------------------------------------------------------------------
# Parameters
PARAM_AXES = (
    "embed",       # d_model dim of weight matrices (FSDP shard target)
    "mlp",         # d_ff dim (TP shard target)
    "heads",       # q heads
    "kv",          # kv heads
    "head_dim",
    "vocab",
    "expert",      # MoE expert dim -> MIMD segments
    "layers",      # stacked scan dim
    "conv",        # temporal conv taps
)
# Activations / caches
ACT_AXES = (
    "act_batch",
    "act_seq",
    "act_embed",
    "act_heads",
    "act_kv",
    "act_hd",
    "act_ff",
    "act_vocab",
    "act_expert",
    "act_cap",      # MoE capacity slots
    "cache_seq",    # KV-cache sequence dim (decode)
)

Rules = Dict[str, Optional[Tuple[str, ...]]]


def _axis_size(mesh: Optional[Mesh], names: Optional[Tuple[str, ...]]) -> int:
    if mesh is None or not names:
        return 1
    n = 1
    for a in names:
        n *= mesh.shape.get(a, 1)
    return n


def _divides(total: int, mesh: Optional[Mesh], names: Optional[Tuple[str, ...]]) -> bool:
    n = _axis_size(mesh, names)
    return n > 0 and total % n == 0


@dataclass
class Plan:
    """Resolved data-mapping for one (model, shape, mesh) cell."""

    rules: Rules
    mesh: Optional[Mesh]
    cfg: Optional[ModelConfig] = None
    shape: Optional[ShapeConfig] = None
    notes: Tuple[str, ...] = ()
    # thesis Fig 5.13 analogue: fraction of the mesh doing distinct useful work
    segment_utilization: float = 1.0
    segments: Dict[str, int] = field(default_factory=dict)

    def spec(self, *logical: Optional[str],
             dims: Optional[Tuple[int, ...]] = None) -> P:
        """PartitionSpec for a tensor tagged with logical axis names.

        Two passes: (1) base assignments (a mesh axis may appear once);
        (2) ZeRO-extra — 'embed'-tagged dims absorb any mesh axes listed in
        rules['_embed_extra'] that pass 1 left unused, so parameters with no
        TP-shardable dim (e.g. attention weights when heads don't divide the
        mesh) still shard fully instead of replicating.

        When ``dims`` (the tensor shape) is given, axes that do not evenly
        divide their dimension are dropped right-to-left — "allocate only
        what fits" made shape-exact.
        """
        parts: list = []
        used: set = set()
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name)
            if not axes:
                parts.append(None)
                continue
            ax = tuple(a for a in axes if a not in used)
            used.update(ax)
            parts.append(ax if ax else None)
        extra = self.rules.get("_embed_extra") or ()
        free = tuple(a for a in extra if a not in used)
        if free:
            for i, name in enumerate(logical):
                if name == "embed":
                    cur = parts[i]
                    cur_t = () if cur is None else (
                        cur if isinstance(cur, tuple) else (cur,))
                    parts[i] = cur_t + free
                    break
        if dims is not None:
            for i, p in enumerate(parts):
                if p is None:
                    continue
                ax = p if isinstance(p, tuple) else (p,)
                while ax and dims[i] % _axis_size(self.mesh, ax) != 0:
                    ax = ax[:-1]
                parts[i] = ax or None
        parts = [p[0] if isinstance(p, tuple) and len(p) == 1 else p
                 for p in parts]
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))


# ---------------------------------------------------------------------------
# Plan context (threaded through model code via `constrain`)
# ---------------------------------------------------------------------------
_state = threading.local()


def current_plan() -> Optional[Plan]:
    return getattr(_state, "plan", None)


@contextlib.contextmanager
def use_plan(plan: Optional[Plan]):
    prev = getattr(_state, "plan", None)
    _state.plan = plan
    try:
        yield plan
    finally:
        _state.plan = prev


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a plan.

    This is the moral equivalent of MIMDRAM's mat-assignment directives: model
    code declares *what* an axis means, the plan decides *where* it lives.
    """
    plan = current_plan()
    if plan is None or plan.mesh is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical axes {logical}")
    # under a partial-manual shard_map (Proteus cross-pod step) XLA's SPMD
    # partitioner CHECK-fails on many constraint/reshard patterns
    # (spmd_partitioner_util.cc:504); let GSPMD propagate freely there.
    if in_manual_context():
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, plan.spec(*logical, dims=tuple(x.shape)))
    )


# ---------------------------------------------------------------------------
# The planner (thesis compiler-pass analogue)
# ---------------------------------------------------------------------------
def plan_sharding(
    cfg: ModelConfig,
    shape: Optional[ShapeConfig] = None,
    mesh: Optional[Mesh] = None,
    overrides: Optional[Mapping[str, Optional[Tuple[str, ...]]]] = None,
) -> Plan:
    """Allocate mesh resources to logical axes for one cell.

    Strategy (priority order, mirroring MIMDRAM's VF-driven allocation):
      data-like mesh axes ('pod','data')  <- batch; spill to sequence (SP)
                                             when batch VF is too small;
      'model' axis                        <- experts (MoE MIMD segments) for
                                             FFN, heads/d_ff for attention/
                                             dense, vocab for the LM head,
                                             cache_seq for decode KV caches.
    Rules are dropped (axis -> None) whenever the dimension size does not
    divide the assigned mesh extent — the "allocate only what fits" rule.
    """
    notes = []
    mesh_axes = dict(mesh.shape) if mesh is not None else {}
    has_pod = "pod" in mesh_axes
    data_axes: Tuple[str, ...] = (("pod", "data") if has_pod else ("data",))
    data_axes = tuple(a for a in data_axes if a in mesh_axes)
    model_ax: Tuple[str, ...] = ("model",) if "model" in mesh_axes else ()

    n_data = _axis_size(mesh, data_axes)
    n_model = _axis_size(mesh, model_ax)

    gb = shape.global_batch if shape is not None else 0
    seq = shape.seq_len if shape is not None else 0
    mode = shape.mode if shape is not None else "train"

    # ---- batch / sequence onto data-like axes -----------------------------
    batch_axes: Optional[Tuple[str, ...]] = None
    seq_axes: Optional[Tuple[str, ...]] = None
    if gb and n_data > 1:
        if gb % n_data == 0:
            batch_axes = data_axes
        else:
            # partial allocation: use the largest prefix that divides.
            acc: Tuple[str, ...] = ()
            for a in data_axes:
                cand = acc + (a,)
                if gb % _axis_size(mesh, cand) == 0:
                    acc = cand
            batch_axes = acc or None
            rest = tuple(a for a in data_axes if a not in (batch_axes or ()))
            if rest and seq and _divides(seq, mesh, rest):
                seq_axes = rest  # sequence-parallel spill (SP)
                notes.append(f"SP: seq over {rest} (batch VF {gb} < {n_data})")
            elif rest:
                notes.append(f"idle data axes {rest}: batch VF {gb} too small")

    # ---- model axis --------------------------------------------------------
    eff_heads = cfg.tp_pad_heads or cfg.num_heads
    heads_ok = eff_heads % max(n_model, 1) == 0
    kv_ok = cfg.num_kv_heads % max(n_model, 1) == 0
    ff_ok = cfg.d_ff and cfg.d_ff % max(n_model, 1) == 0
    vocab_ok = cfg.vocab_size % max(n_model, 1) == 0
    expert_ok = cfg.num_experts and cfg.num_experts % max(n_model, 1) == 0

    # MoE capacity sharding: when experts cannot claim the model axis
    # (E !% n_model), the capacity dim takes it instead (group-local
    # dispatch buffers stay distributed). Shape-exact divisibility is
    # enforced per-tensor by Plan.spec(dims=...).
    cap_axes = model_ax if (cfg.num_experts and not expert_ok) else None

    serving = mode in ("prefill", "decode")
    # Serving keeps parameters off the data axes (no gradient reduction to
    # amortize per-step FSDP gathers against): params live TP-sharded on the
    # model axis (directly or via _embed_extra) and replicated across data —
    # UNLESS the model-axis shards alone cannot fit HBM (kimi-class): then
    # serving falls back to full FSDP sharding and pays the per-layer gather.
    from repro.configs.base import param_count  # noqa: PLC0415
    dtype_bytes = 2 if serving or cfg.param_dtype == "bfloat16" else 4
    per_model_shard = param_count(cfg) * dtype_bytes / max(n_model, 1)
    serving_needs_fsdp = serving and per_model_shard > 8e9
    fsdp_axes = (data_axes if (n_data > 1 and
                               (not serving or serving_needs_fsdp)) else None)
    if serving_needs_fsdp:
        notes.append("serving: params exceed model-axis HBM -> FSDP fallback")

    rules: Rules = {
        # params
        "embed": fsdp_axes,                           # FSDP (train only)
        "_embed_extra": model_ax,
        "mlp": model_ax if ff_ok else None,
        "heads": model_ax if heads_ok else None,
        "kv": model_ax if kv_ok else None,
        "head_dim": None,
        "vocab": model_ax if vocab_ok else None,
        "expert": model_ax if expert_ok else None,
        "layers": None,
        "conv": None,
        # activations
        "act_batch": batch_axes,
        "act_seq": seq_axes,
        "act_embed": None,
        "act_heads": model_ax if heads_ok else None,
        "act_kv": model_ax if kv_ok else None,
        "act_hd": None,
        "act_ff": model_ax if ff_ok else None,
        "act_vocab": model_ax if vocab_ok else None,
        "act_expert": model_ax if expert_ok else None,
        "act_cap": cap_axes,
        "cache_seq": None,
    }

    if cfg.num_experts and expert_ok:
        notes.append(
            f"MIMD segments: {cfg.num_experts} experts over {n_model}-wide model axis "
            f"({cfg.num_experts // max(n_model,1)} experts/segment)"
        )

    # serving: the KV cache dominates memory. Shard a dim whose in-place
    # update (dynamic-update-slice at the write slot) stays device-local:
    # kv-heads if they divide the model axis, else head_dim (scores psum per
    # tile is tiny at q_len=1). Sharding cache_seq would force SPMD to
    # replicate the cache around every DUS. Dedicated cache_* names keep
    # activation sharding untouched.
    rules["cache_kv"] = model_ax if kv_ok else None
    rules["cache_hd"] = None
    if serving and n_model > 1 and not kv_ok:
        if cfg.resolved_head_dim % n_model == 0:
            rules["cache_hd"] = model_ax
            notes.append("serving: KV cache sharded over head_dim (model axis)")

    if not heads_ok and model_ax:
        notes.append(
            f"heads {eff_heads} !% model {n_model}: attention TP via d_ff/vocab only"
        )
    if not kv_ok and model_ax:
        notes.append(f"kv heads {cfg.num_kv_heads} !% model {n_model}: kv replicated")

    if overrides:
        rules.update(dict(overrides))

    # ---- segment utilization (thesis SIMD-utilization metric) --------------
    util = 1.0
    if mesh is not None:
        used = 1
        total = 1
        for a, s in mesh_axes.items():
            total *= s
        batch_used = _axis_size(mesh, rules.get("act_batch")) * _axis_size(
            mesh, rules.get("act_seq")
        )
        model_used = max(
            _axis_size(mesh, rules.get("act_expert")),
            _axis_size(mesh, rules.get("act_heads")),
            _axis_size(mesh, rules.get("act_ff")),
            _axis_size(mesh, rules.get("cache_seq")),
            1,
        )
        used = batch_used * model_used
        util = used / max(total, 1)

    segs = {
        "expert_segments": min(cfg.num_experts or 1, n_model or 1),
        "data_ways": _axis_size(mesh, rules.get("act_batch")),
        "seq_ways": _axis_size(mesh, rules.get("act_seq")),
        "model_ways": n_model,
    }

    return Plan(
        rules=rules,
        mesh=mesh,
        cfg=cfg,
        shape=shape,
        notes=tuple(notes),
        segment_utilization=util,
        segments=segs,
    )


# ---------------------------------------------------------------------------
# Native vector reduction (thesis §5.2: cross-mat reduction trees)
# ---------------------------------------------------------------------------
def reduce_hierarchical(
    x: jax.Array, axes: Sequence[str], pod_axis: str = "pod"
) -> jax.Array:
    """psum with pod-local-first scheduling, for use inside shard_map.

    MIMDRAM performs reductions first within a mat, then across mats through
    the low-cost inter-mat interconnect. The ICI analogue: reduce within a pod
    (fast links) before crossing the inter-pod links, so the slow hop carries
    a single pre-reduced operand.
    """
    local = tuple(a for a in axes if a != pod_axis)
    if local:
        x = jax.lax.psum(x, local)
    if pod_axis in axes:
        x = jax.lax.psum(x, pod_axis)
    return x


def vf_report(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, int]:
    """Available parallelism per logical dimension (thesis Fig 5.1 analogue)."""
    return {
        "batch": shape.global_batch,
        "seq": shape.seq_len if shape.mode != "decode" else 1,
        "heads": cfg.num_heads,
        "kv_heads": cfg.num_kv_heads,
        "d_ff": cfg.d_ff,
        "experts": cfg.num_experts,
        "vocab": cfg.vocab_size,
    }
