"""Optimizers: AdamW, Adafactor (factored 2nd moment), SGD — pure JAX.

Optimizer state reuses the parameters' logical sharding axes (ZeRO-style:
states live wherever their parameter shard lives), so the MIMDRAM planner
shards them with zero extra policy. ``state_specs`` feeds the dry-run the
abstract state tree.

Adafactor exists because of the kimi-k2 memory budget: 1T params cannot hold
12 B/param Adam state in 512 x 16 GB HBM (see configs/kimi_k2_1t.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models import module as mod


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = base_lr * (final_frac + (1 - final_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree: Any) -> jax.Array:
    s = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree_util.tree_leaves(tree))
    return jnp.sqrt(s)


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), n


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]                     # params -> state
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (g, state, p) -> (p', s')
    state_specs: Callable[[Any], Any]              # param specs -> state specs


def _f32_like_specs(specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: mod.ParamSpec(s.shape, jnp.float32, s.logical_axes, ("zeros",)),
        specs, is_leaf=mod.is_spec)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(run: RunConfig) -> Optimizer:
    lr_fn = cosine_schedule(run.learning_rate, run.warmup_steps, run.total_steps)
    b1, b2, wd, eps = run.b1, run.b2, run.weight_decay, 1e-8

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(z, params),
            "nu": jax.tree_util.tree_map(z, params),
        }

    def update(grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, run.grad_clip)
        step = state["step"] + 1
        lr = lr_fn(step)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_v = treedef.flatten_up_to(state["nu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        return new_p, {"step": step, "mu": new_m, "nu": new_v}

    def state_specs(param_specs):
        return {
            "step": mod.ParamSpec((), jnp.int32, (), ("zeros",)),
            "mu": _f32_like_specs(param_specs),
            "nu": _f32_like_specs(param_specs),
        }

    return Optimizer("adamw", init, update, state_specs)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored second moment
# ---------------------------------------------------------------------------
def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor(run: RunConfig) -> Optimizer:
    lr_fn = cosine_schedule(run.learning_rate, run.warmup_steps, run.total_steps)
    eps1, eps2, clip_d = 1e-30, 1e-3, 1.0
    wd = run.weight_decay

    def init(params):
        def st(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {
            "step": jnp.zeros((), jnp.int32),
            "v": jax.tree_util.tree_map(st, params),
        }

    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, run.grad_clip)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr = lr_fn(step)
        b2 = 1.0 - t ** -0.8

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps1
            if _factored(p.shape):
                vr = b2 * s["vr"] + (1 - b2) * g2.mean(axis=-1)
                vc = b2 * s["vc"] + (1 - b2) * g2.mean(axis=-2)
                denom = vr.sum(axis=-1, keepdims=True)
                vhat = (vr[..., None] * vc[..., None, :]
                        / jnp.maximum(denom[..., None], eps1))
                u = gf * jax.lax.rsqrt(jnp.maximum(vhat, eps1))
                ns = {"vr": vr, "vc": vc}
            else:
                v = b2 * s["v"] + (1 - b2) * g2
                u = gf * jax.lax.rsqrt(jnp.maximum(v, eps1))
                ns = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_d)
            pf = p.astype(jnp.float32)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(pf * pf)))
            new_p = pf - lr * scale * u - lr * wd * pf
            return new_p.astype(p.dtype), ns

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_s = treedef.flatten_up_to(state["v"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_s = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_p, {"step": step, "v": new_s}

    def state_specs(param_specs):
        def st(s):
            if _factored(s.shape):
                return {
                    "vr": mod.ParamSpec(s.shape[:-1], jnp.float32,
                                        s.logical_axes[:-1], ("zeros",)),
                    "vc": mod.ParamSpec(s.shape[:-2] + s.shape[-1:], jnp.float32,
                                        s.logical_axes[:-2] + s.logical_axes[-1:],
                                        ("zeros",)),
                }
            return {"v": mod.ParamSpec(s.shape, jnp.float32, s.logical_axes,
                                       ("zeros",))}
        return {
            "step": mod.ParamSpec((), jnp.int32, (), ("zeros",)),
            "v": jax.tree_util.tree_map(st, param_specs, is_leaf=mod.is_spec),
        }

    return Optimizer("adafactor", init, update, state_specs)


# ---------------------------------------------------------------------------
# SGD (momentum)
# ---------------------------------------------------------------------------
def sgd(run: RunConfig, momentum: float = 0.9) -> Optimizer:
    lr_fn = cosine_schedule(run.learning_rate, run.warmup_steps, run.total_steps)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        grads, _ = clip_by_global_norm(grads, run.grad_clip)
        step = state["step"] + 1
        lr = lr_fn(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_m = treedef.flatten_up_to(state["mu"])
        flat_p = treedef.flatten_up_to(params)
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
                {"step": step,
                 "mu": jax.tree_util.tree_unflatten(treedef,
                                                    [o[1] for o in out])})

    def state_specs(param_specs):
        return {
            "step": mod.ParamSpec((), jnp.int32, (), ("zeros",)),
            "mu": _f32_like_specs(param_specs),
        }

    return Optimizer("sgd", init, update, state_specs)


def make_optimizer(name: str, run: RunConfig) -> Optimizer:
    if name == "adamw":
        return adamw(run)
    if name == "adafactor":
        return adafactor(run)
    if name == "sgd":
        return sgd(run)
    raise ValueError(f"unknown optimizer {name!r}")
