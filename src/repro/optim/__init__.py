from repro.optim.optimizers import (Optimizer, adafactor, adamw,
                                    cosine_schedule, global_norm,
                                    make_optimizer, sgd)

__all__ = ["Optimizer", "adamw", "adafactor", "sgd", "make_optimizer",
           "cosine_schedule", "global_norm"]
