"""Encoder-decoder LM (seamless-m4t-large-v2 backbone).

The audio modality frontend is a STUB: the encoder consumes precomputed frame
embeddings (batch, src_len, d_model) supplied by ``input_specs()`` — per the
assignment rules. The text decoder has causal self-attention + cross-attention
and a KV-cache decode path.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import (aligned_cache_len, chunked_attention, dense,
                                 gated_mlp, kv_cache_axes, kv_cache_init,
                                 kv_cache_len, kv_cache_store,
                                 kv_cache_update, kv_cast, maybe_kv_quantize,
                                 rms_norm, rope, softmax_xent)
from repro.models.model import attn_param_specs, mlp_param_specs, qkv


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dt(cfg.param_dtype)
        self.cdtype = _dt(cfg.compute_dtype)

    # -- specs ----------------------------------------------------------------
    def _enc_layer(self):
        cfg = self.cfg
        return {
            "ln1": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "attn": attn_param_specs(cfg, self.dtype),
            "ln2": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "mlp": mlp_param_specs(cfg, self.dtype),
        }

    def _dec_layer(self):
        cfg = self.cfg
        return {
            "ln1": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "self_attn": attn_param_specs(cfg, self.dtype),
            "ln_x": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "cross_attn": attn_param_specs(cfg, self.dtype),
            "ln2": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "mlp": mlp_param_specs(cfg, self.dtype),
        }

    def param_specs(self):
        cfg = self.cfg
        return {
            "embed": mod.spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              self.dtype),
            "enc_blocks": mod.stack_tree(self._enc_layer(),
                                         cfg.num_encoder_layers),
            "enc_norm": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "dec_blocks": mod.stack_tree(self._dec_layer(), cfg.num_layers),
            "final_norm": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "head": mod.spec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"),
                             self.dtype),
        }

    # -- encoder ----------------------------------------------------------------
    def encode(self, params, src_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = src_embeds.astype(self.cdtype)
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)

        def body(carry, p):
            h = optimization_barrier(carry)
            p = mod.constrain_tree(p, self._enc_layer())
            xn = rms_norm(h, p["ln1"], cfg.norm_eps)
            q, k, v = qkv(cfg, p["attn"], xn, positions)
            o = chunked_attention(q, k, v, causal=False, q_offset=0)
            h = h + dense(o, p["attn"]["w_o"], "bshe,hed->bsd")
            h = h + gated_mlp(rms_norm(h, p["ln2"], cfg.norm_eps),
                              p["mlp"]["wi_gate"], p["mlp"]["wi_up"],
                              p["mlp"]["wo"])
            return constrain(h, "act_batch", "act_seq", "act_embed"), None

        fn = body
        if cfg.remat != "none":
            fn = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # -- decoder (teacher-forced) -------------------------------------------------
    def _dec_block(self, p, h, enc_out, positions):
        cfg = self.cfg
        xn = rms_norm(h, p["ln1"], cfg.norm_eps)
        q, k, v = qkv(cfg, p["self_attn"], xn, positions)
        o = chunked_attention(q, k, v, causal=True, q_offset=0)
        h = h + dense(o, p["self_attn"]["w_o"], "bshe,hed->bsd")
        # cross attention (no RoPE)
        xn = rms_norm(h, p["ln_x"], cfg.norm_eps)
        qx = dense(xn, p["cross_attn"]["w_q"], "bsd,dhe->bshe")
        kx = dense(enc_out, p["cross_attn"]["w_k"], "bsd,dhe->bshe")
        vx = dense(enc_out, p["cross_attn"]["w_v"], "bsd,dhe->bshe")
        ox = chunked_attention(qx, kx, vx, causal=False, q_offset=0)
        h = h + dense(ox, p["cross_attn"]["w_o"], "bshe,hed->bsd")
        h = h + gated_mlp(rms_norm(h, p["ln2"], cfg.norm_eps),
                          p["mlp"]["wi_gate"], p["mlp"]["wi_up"], p["mlp"]["wo"])
        return constrain(h, "act_batch", "act_seq", "act_embed")

    def forward(self, params, src_embeds, tokens):
        cfg = self.cfg
        enc_out = self.encode(params, src_embeds)
        x = params["embed"].astype(self.cdtype)[tokens]
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def body(carry, p):
            carry = optimization_barrier(carry)
            p = mod.constrain_tree(p, self._dec_layer())
            return self._dec_block(p, carry, enc_out, positions), None

        fn = body
        if cfg.remat != "none":
            fn = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["head"], "bsd,dv->bsv")

    def loss(self, params, batch):
        logits = self.forward(params, batch["src_embeds"], batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                            batch.get("loss_mask"))

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        L = cfg.num_layers
        dh = cfg.resolved_head_dim
        src = int(max_len * cfg.src_len_ratio)
        # cross kv is read-only after prefill and never grows: it stays in
        # the contiguous layout (page_size=0) even when the growing self
        # cache is paged.
        kv = (batch, aligned_cache_len(max_len), cfg.num_kv_heads, dh)
        xkv = (batch, src, cfg.num_kv_heads, dh)
        return {
            "k": kv_cache_init((L,) + kv, self.cdtype),
            "v": kv_cache_init((L,) + kv, self.cdtype),
            "xk": kv_cache_init((L,) + xkv, self.cdtype, page_size=0),
            "xv": kv_cache_init((L,) + xkv, self.cdtype, page_size=0),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self):
        axes = ("layers", "act_batch", "cache_seq", "cache_kv", "cache_hd")
        return {"k": kv_cache_axes(axes), "v": kv_cache_axes(axes),
                "xk": kv_cache_axes(axes, page_size=0),
                "xv": kv_cache_axes(axes, page_size=0),
                "pos": ("act_batch",)}

    def prefill(self, params, batch, max_len=None, full_logits=False):
        """Encode source + run decoder over the token prefix, build caches.

        With ``max_len`` the self-attention cache is pre-sized to ``max_len``
        positions (decode writes at ``pos`` directly; positions >= ``pos`` are
        masked via ``kv_valid_len``) — no repad between prefill and decode.
        The cross-attention cache keeps the exact source length.
        """
        cfg = self.cfg
        enc_out = self.encode(params, batch["src_embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        T = aligned_cache_len(max(max_len or S, S))
        x = params["embed"].astype(self.cdtype)[tokens]
        positions = jnp.arange(S, dtype=jnp.int32)

        def store(k):
            # S <= T, so the ring store is exactly pad-to-T (shift 0)
            return kv_cache_store(k.astype(self.cdtype), S, T)

        def body(carry, p):
            h = carry
            p = mod.constrain_tree(p, self._dec_layer())
            xn = rms_norm(h, p["ln1"], cfg.norm_eps)
            q, k, v = qkv(cfg, p["self_attn"], xn, positions)
            o = chunked_attention(q, k, v, causal=True, q_offset=0)
            h = h + dense(o, p["self_attn"]["w_o"], "bshe,hed->bsd")
            xn = rms_norm(h, p["ln_x"], cfg.norm_eps)
            qx = dense(xn, p["cross_attn"]["w_q"], "bsd,dhe->bshe")
            kx = dense(enc_out, p["cross_attn"]["w_k"], "bsd,dhe->bshe")
            vx = dense(enc_out, p["cross_attn"]["w_v"], "bsd,dhe->bshe")
            ox = chunked_attention(qx, kx, vx, causal=False, q_offset=0)
            h = h + dense(ox, p["cross_attn"]["w_o"], "bshe,hed->bsd")
            h = h + gated_mlp(rms_norm(h, p["ln2"], cfg.norm_eps),
                              p["mlp"]["wi_gate"], p["mlp"]["wi_up"],
                              p["mlp"]["wo"])
            return h, (store(k), store(v),
                       maybe_kv_quantize(kx.astype(self.cdtype)),
                       maybe_kv_quantize(vx.astype(self.cdtype)))

        x, (ck, cv, cxk, cxv) = jax.lax.scan(body, x, params["dec_blocks"])
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x if full_logits else x[:, -1:], params["head"],
                       "bsd,dv->bsv")
        cache = {"k": ck, "v": cv, "xk": cxk, "xv": cxv,
                 "pos": jnp.full((B,), S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]
        pos = cache["pos"]                                   # (B,)
        positions = pos[:, None].astype(jnp.int32)
        T = kv_cache_len(cache["k"])

        def body(carry, xs):
            h = carry
            p, ck, cv, xk, xv = xs
            p = mod.constrain_tree(p, self._dec_layer())
            xn = rms_norm(h, p["ln1"], cfg.norm_eps)
            q, k, v = qkv(cfg, p["self_attn"], xn, positions)
            ck = kv_cache_update(ck, k, jnp.minimum(pos, T - 1))
            cv = kv_cache_update(cv, v, jnp.minimum(pos, T - 1))
            o = chunked_attention(q, kv_cast(ck, h.dtype), kv_cast(cv, h.dtype),
                                  causal=True, q_offset=pos,
                                  kv_valid_len=pos + 1, chunk_kv=min(1024, T))
            h = h + dense(o, p["self_attn"]["w_o"], "bshe,hed->bsd")
            xn = rms_norm(h, p["ln_x"], cfg.norm_eps)
            qx = dense(xn, p["cross_attn"]["w_q"], "bsd,dhe->bshe")
            ox = chunked_attention(qx, kv_cast(xk, h.dtype), kv_cast(xv, h.dtype),
                                   causal=False, q_offset=0)
            h = h + dense(ox, p["cross_attn"]["w_o"], "bshe,hed->bsd")
            h = h + gated_mlp(rms_norm(h, p["ln2"], cfg.norm_eps),
                              p["mlp"]["wi_gate"], p["mlp"]["wi_up"],
                              p["mlp"]["wo"])
            return h, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            body, x,
            (params["dec_blocks"], cache["k"], cache["v"], cache["xk"],
             cache["xv"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["head"], "bsd,dv->bsv")
        new_cache = dict(cache, k=ck, v=cv, pos=pos + 1)
        return logits, new_cache
