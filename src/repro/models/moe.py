"""Mixture-of-Experts FFN — the MIMDRAM MIMD-segment showcase.

Experts are independent programs executing concurrently in different mesh
segments (expert dim sharded over the 'model' axis). Token dispatch is the
capacity-bounded scatter/gather formulation: O(T*k) routing work plus
O(E*C*d*ff) expert compute — no O(T*E*C) one-hot tensors.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import dense


def moe_param_specs(cfg: ModelConfig, dtype: Any) -> Dict[str, mod.ParamSpec]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": mod.spec((d, e), ("embed", "expert"), dtype),
        "wi_gate": mod.spec((e, d, f), ("expert", "embed", "mlp"), dtype, ("normal", 1)),
        "wi_up": mod.spec((e, d, f), ("expert", "embed", "mlp"), dtype, ("normal", 1)),
        "wo": mod.spec((e, f, d), ("expert", "mlp", "embed"), dtype, ("normal", 1)),
    }


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(tokens * cfg.experts_per_token * cfg.capacity_factor // cfg.num_experts)
    return max(c, cfg.experts_per_token)


def _dispatch_plan(T: int):
    """(n_groups, manual_axes, mesh): one dispatch group per data shard so
    routing (cumsum, scatter, gather) never crosses devices — the
    MIMDRAM-style 'keep work inside the mat' rule. Off-mesh: (1, (), None).
    """
    from repro.core.mimdram import _axis_size, current_plan  # noqa: PLC0415
    plan = current_plan()
    if plan is None or plan.mesh is None:
        return 1, (), None
    axes = plan.rules.get("act_batch") or ()
    g = _axis_size(plan.mesh, axes)
    if g <= 1 or T % g != 0:
        return 1, (), None
    # when already inside a shard_map (e.g. the Proteus cross-pod step), the
    # nested shard_map must carry the context mesh's axis types
    ctx = compat.context_mesh()
    mesh = ctx if (ctx is not None
                   and set(plan.mesh.axis_names) <= set(ctx.axis_names)) \
        else compat.abstract_mesh(plan.mesh)
    return g, tuple(axes), mesh


def _scatter_to_buffers(xt, idx, slot, keep, E: int, C: int, axes, mesh):
    """(G,Tl,D),(G,Tl,K)x3 -> (E,G,C,D). Manual over the data axes so the
    scatter is provably device-local (GSPMD would otherwise all-reduce the
    whole buffer); expert/model axes stay auto."""

    def local(xt1, idx1, slot1, keep1):
        # shapes (1, Tl, ...) per shard
        buf = jnp.zeros((E, 1, C, xt1.shape[-1]), xt1.dtype)
        scat = xt1[0, :, None, :] * keep1[0, ..., None]
        return buf.at[idx1[0], 0, slot1[0]].add(scat, mode="drop")

    if mesh is None:
        return local(xt, idx, slot, keep)
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415
    sm = compat.shard_map(
        local, mesh=mesh,                 # abstract; composes in context
        in_specs=(P(axes), P(axes), P(axes), P(axes)),
        out_specs=P(None, axes),
        axis_names=frozenset(axes), check_vma=False)
    return sm(xt, idx, slot, keep)


def _gather_from_buffers(y_buf, idx, slot, axes, mesh):
    """(E,G,C,D),(G,Tl,K)x2 -> (G,Tl,K,D), group-local."""

    def local(yb1, idx1, slot1):
        return yb1[:, 0][idx1[0], slot1[0]][None]            # (1,Tl,K,D)

    if mesh is None:
        return local(y_buf, idx, slot)
    from jax.sharding import PartitionSpec as P  # noqa: PLC0415
    sm = compat.shard_map(
        local, mesh=mesh,                 # abstract; composes in context
        in_specs=(P(None, axes), P(axes), P(axes)),
        out_specs=P(axes),
        axis_names=frozenset(axes), check_vma=False)
    return sm(y_buf, idx, slot)


def moe_ffn(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). Dropped-token, capacity-bounded top-k MoE.

    Dispatch is *group-local* (GShard/Switch-style): tokens are routed within
    their data shard's group; per-group capacity buffers keep scatter/gather
    and the position cumsum device-local, and only the expert einsum crosses
    the mesh (expert/capacity dims on the model axis).
    """
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    G, dax, mesh = _dispatch_plan(T)
    Tl = T // G
    C = _capacity(cfg, Tl)                                   # per-group
    xt = x.reshape(G, Tl, D)
    xt = constrain(xt, "act_batch", None, None)

    logits = dense(xt, p["router"], "gtd,de->gte").astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)                      # (G, Tl, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position within the group's expert buffer: group-local cumsum
    oh = jax.nn.one_hot(idx.reshape(G, Tl * K), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=1) - oh                        # slots before mine
    pos = (pos * oh).sum(-1).reshape(G, Tl, K)
    keep = (pos < C).astype(x.dtype)
    slot = jnp.minimum(pos, C - 1)

    # scatter tokens into (E, G, C, D) buffers (gates applied at combine);
    # device-local by construction (manual over the data axes).
    buf = _scatter_to_buffers(xt, idx, slot, keep, E, C, dax, mesh)
    buf = constrain(buf, "act_expert", "act_batch", "act_cap", None)

    # expert FFN: independent per-segment programs (MIMD over 'model' axis)
    g = jnp.einsum("egcd,edf->egcf", buf, p["wi_gate"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    u = jnp.einsum("egcd,edf->egcf", buf, p["wi_up"].astype(x.dtype),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(g) * u
    h = constrain(h, "act_expert", "act_batch", "act_cap", "act_ff")
    y_buf = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    y_buf = constrain(y_buf, "act_expert", "act_batch", "act_cap", None)

    # gather back and combine with gates (group-local)
    y = _gather_from_buffers(y_buf, idx, slot, dax, mesh)    # (G, Tl, K, D)
    y = (y * (gate[..., None].astype(x.dtype)) * keep[..., None]).sum(axis=2)
    return y.reshape(B, S, D)


def moe_ffn_ref(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array,
                respect_capacity: bool = True) -> jax.Array:
    """Dense oracle: every token through every expert, masked combine (tests)."""
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, T)
    xt = x.reshape(T, D).astype(jnp.float32)
    logits = xt @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_idx = idx.reshape(-1)
    oh = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
    pos = ((jnp.cumsum(oh, axis=0) - oh) * oh).sum(-1).reshape(T, K)
    keep = (pos < C) if respect_capacity else jnp.ones_like(pos, bool)

    y = jnp.zeros((T, D), jnp.float32)
    for e in range(E):
        g = jax.nn.silu(xt @ p["wi_gate"][e].astype(jnp.float32))
        u = xt @ p["wi_up"][e].astype(jnp.float32)
        ye = (g * u) @ p["wo"][e].astype(jnp.float32)
        w = ((idx == e) * keep * gate).sum(axis=-1)          # (T,)
        y = y + ye * w[:, None]
    return y.reshape(B, S, D).astype(x.dtype)


def load_balance_loss(router_probs: jax.Array, idx: jax.Array, E: int) -> jax.Array:
    """Switch-style auxiliary loss (fraction-routed * mean-prob)."""
    me = router_probs.mean(axis=0)                           # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / jnp.maximum(idx.size, 1)
    return E * jnp.sum(me * ce)
