"""RG-LRU recurrent temporal-mixing block (RecurrentGemma / Griffin).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))

Train/prefill use an associative scan (parallel, O(log T) depth); decode is a
single O(1) state update — the bounded-state property that makes long_500k
runnable for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import dense


def rglru_param_specs(cfg: ModelConfig, dtype: Any) -> Dict[str, mod.ParamSpec]:
    d = cfg.d_model
    w = cfg.conv_width
    return {
        # gated two-branch temporal block (Griffin recurrent block)
        "w_gate": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_x": mod.spec((d, d), ("embed", "mlp"), dtype),
        "conv_w": mod.spec((w, d), ("conv", "mlp"), dtype),
        "conv_b": mod.spec((d,), ("mlp",), dtype, ("zeros",)),
        "lam": mod.spec((d,), ("mlp",), jnp.float32, ("rglru_lambda",)),
        "w_input_gate": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_rec_gate": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_out": mod.spec((d, d), ("mlp", "embed"), dtype),
    }


def _gates(cfg: ModelConfig, p, xb: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """log a_t (fp32) and gated input branch. xb: (B, S, D)."""
    r = dense(xb, p["w_rec_gate"], "bsd,de->bse").astype(jnp.float32)
    i = dense(xb, p["w_input_gate"], "bsd,de->bse").astype(jnp.float32)
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"]) * jax.nn.sigmoid(r)
    a2 = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * jax.nn.sigmoid(i) * xb.astype(
        jnp.float32
    )
    return log_a, gated


def rglru_scan(cfg: ModelConfig, p, xb: jax.Array,
               h0: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Parallel linear recurrence. xb: (B, S, D) -> (out, h_last)."""
    log_a, b = _gates(cfg, p, xb)
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(op, (a, b), axis=1)
    return h.astype(xb.dtype), h[:, -1]


def rglru_step(cfg: ModelConfig, p, xb: jax.Array,
               h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decode step. xb: (B, 1, D), h: (B, D) fp32 state."""
    log_a, b = _gates(cfg, p, xb)
    h_new = jnp.exp(log_a[:, 0]) * h + b[:, 0]
    return h_new[:, None, :].astype(xb.dtype), h_new


def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                state: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv, width W. x: (B, S, D); state: (B, W-1, D)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+W-1, D)
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype)
    out = out + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return out, new_state


def recurrent_block(cfg: ModelConfig, p, x: jax.Array,
                    state: Dict[str, jax.Array] | None = None
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Griffin recurrent temporal-mixing block (gated two-branch).

    x: (B, S, D). state: {"h": (B,D) fp32, "conv": (B,W-1,D)} or None.
    """
    gate = jax.nn.gelu(dense(x, p["w_gate"], "bsd,de->bse"))
    xb = dense(x, p["w_x"], "bsd,de->bse")
    xb = constrain(xb, "act_batch", "act_seq", "act_ff")
    conv_state = None if state is None else state["conv"]
    xb, new_conv = causal_conv(xb, p["conv_w"], p["conv_b"], conv_state)
    if state is None:
        y, h_last = rglru_scan(cfg, p, xb, None)
    elif x.shape[1] == 1:
        y, h_last = rglru_step(cfg, p, xb, state["h"])
    else:
        y, h_last = rglru_scan(cfg, p, xb, state["h"])
    out = dense(gate * y, p["w_out"], "bse,ed->bsd")
    return out, {"h": h_last, "conv": new_conv}


def init_rglru_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d, w = cfg.d_model, cfg.conv_width
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, w - 1, d), jnp.bfloat16),
    }
