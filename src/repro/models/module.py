"""Minimal pure-JAX module system: param-spec trees with logical sharding axes.

Models declare a pytree of :class:`ParamSpec` (shape, dtype, logical axes,
init recipe). The MIMDRAM planner maps logical axes to mesh axes; the same
spec tree yields concrete params (smoke tests / training) or
``ShapeDtypeStruct`` stand-ins (dry-run — never allocated).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mimdram import Plan


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    logical_axes: Tuple[Optional[str], ...]
    init: Tuple[Any, ...] = ("normal",)  # ("normal"[, fan_in_axis]) | ("zeros",) |
    #                                      ("ones",) | ("rglru_lambda",)


def spec(
    shape: Tuple[int, ...],
    axes: Tuple[Optional[str], ...],
    dtype: Any = jnp.float32,
    init: Tuple[Any, ...] = ("normal",),
) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), dtype, tuple(axes), tuple(init))


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(key: jax.Array, s: ParamSpec) -> jax.Array:
    kind = s.init[0]
    if kind == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if kind == "ones":
        return jnp.ones(s.shape, s.dtype)
    if kind == "rglru_lambda":
        # RG-LRU Λ init: a = sigmoid(Λ) uniform in [0.9, 0.999] (Griffin §2.4)
        u = jax.random.uniform(key, s.shape, jnp.float32, 0.9, 0.999)
        lam = jnp.log(u) - jnp.log1p(-u)  # logit
        return lam.astype(s.dtype)
    if kind == "normal":
        fan_axis = s.init[1] if len(s.init) > 1 else 0
        fan_in = s.shape[fan_axis] if s.shape else 1
        scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape, jnp.float32) * scale).astype(s.dtype)
    raise ValueError(f"unknown init {s.init!r}")


def init_params(specs: Any, key: jax.Array) -> Any:
    """Materialize a spec tree into concrete arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(specs: Any) -> Any:
    """ShapeDtypeStruct stand-ins (dry-run path: no allocation)."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def param_pspecs(specs: Any, plan: Plan) -> Any:
    """PartitionSpec tree via the plan's logical-axis rules (shape-exact)."""
    return jax.tree_util.tree_map(
        lambda s: plan.spec(*s.logical_axes, dims=s.shape), specs,
        is_leaf=is_spec
    )


def constrain_tree(params: Any, specs: Any) -> Any:
    """Re-pin (sliced) params to their plan sharding inside scan bodies.

    Without this, GSPMD may hoist the FSDP all-gather of the *stacked*
    weights out of the layer/microbatch loops, materializing the full
    unsharded parameter tree (observed: 187 GB for mixtral-8x7b). Pinning
    the per-layer slice to its sharded spec forces gather-after-slice.
    """
    from repro.core.mimdram import current_plan  # noqa: PLC0415
    from jax.sharding import NamedSharding  # noqa: PLC0415
    from repro.compat import in_manual_context  # noqa: PLC0415

    plan = current_plan()
    if plan is None or plan.mesh is None:
        return params
    # inside a partial-manual shard_map (Proteus cross-pod step) the SPMD
    # partitioner rejects sharding constraints on scan-sliced params
    # (spmd_partitioner_util CHECK); skip pinning there — params are
    # pod-replicated in that mode so the hoisting pathology is bounded.
    if in_manual_context():
        return params

    def pin(x, s):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(plan.mesh,
                             plan.spec(*s.logical_axes, dims=s.shape)))

    # traversal follows `params`; spec subtrees align leaf-for-leaf
    return jax.tree_util.tree_map(pin, params, specs)


def param_bytes(specs: Any) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
    return total


def count_params(specs: Any) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    )


def stack_specs(s: ParamSpec, n: int) -> ParamSpec:
    """Prepend a scanned 'layers' axis to a spec."""
    return ParamSpec(
        (n,) + s.shape, s.dtype, ("layers",) + s.logical_axes, s.init
    )


def stack_tree(specs: Any, n: int) -> Any:
    return jax.tree_util.tree_map(lambda s: stack_specs(s, n), specs, is_leaf=is_spec)
