"""Recurrent-family LMs: GriffinLM (recurrentgemma) and XLSTMLM (xlstm).

Both have O(1)-in-sequence decode state — the sub-quadratic families that run
the long_500k cell.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import (aligned_cache_len, chunked_attention, dense,
                                 gated_mlp, kv_cache_axes, kv_cache_init,
                                 kv_cache_len, kv_cache_store,
                                 kv_cache_update, kv_cast, ring_cache_update,
                                 ring_position_ids, rms_norm, softmax_xent)
from repro.models.model import attn_param_specs, mlp_param_specs, qkv
from repro.models.rglru import (init_rglru_state, recurrent_block,
                                rglru_param_specs)
from repro.models.xlstm import (init_mlstm_state, init_slstm_state,
                                mlstm_chunked, mlstm_param_specs, mlstm_step,
                                slstm_param_specs, slstm_scan)


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ===========================================================================
# GriffinLM — pattern (recurrent, recurrent, local-attention) x groups
# ===========================================================================
class GriffinLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dt(cfg.param_dtype)
        self.cdtype = _dt(cfg.compute_dtype)
        self.n_groups = cfg.num_layers // 3
        self.n_tail = cfg.num_layers - 3 * self.n_groups  # trailing recurrent

    # -- specs ----------------------------------------------------------------
    def _rec_layer_specs(self):
        cfg = self.cfg
        return {
            "ln1": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "rec": rglru_param_specs(cfg, self.dtype),
            "ln2": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "mlp": mlp_param_specs(cfg, self.dtype),
        }

    def _attn_layer_specs(self):
        cfg = self.cfg
        return {
            "ln1": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "attn": attn_param_specs(cfg, self.dtype),
            "ln2": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "mlp": mlp_param_specs(cfg, self.dtype),
        }

    def _group_specs(self):
        return {
            "rec1": self._rec_layer_specs(),
            "rec2": self._rec_layer_specs(),
            "attn": self._attn_layer_specs(),
        }

    def param_specs(self):
        cfg = self.cfg
        group = self._group_specs()
        specs = {
            "embed": mod.spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              self.dtype),
            "groups": mod.stack_tree(group, self.n_groups),
            "tail": [self._rec_layer_specs() for _ in range(self.n_tail)],
            "final_norm": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
        }
        return specs

    # -- layers ---------------------------------------------------------------
    def _rec_layer(self, p, x, state):
        cfg = self.cfg
        y, new_state = recurrent_block(cfg, p["rec"],
                                       rms_norm(x, p["ln1"], cfg.norm_eps), state)
        x = x + y
        x = x + gated_mlp(rms_norm(x, p["ln2"], cfg.norm_eps),
                          p["mlp"]["wi_gate"], p["mlp"]["wi_up"], p["mlp"]["wo"])
        return constrain(x, "act_batch", "act_seq", "act_embed"), new_state

    def _attn_layer(self, p, x, cache, pos, pos_ids):
        """Local MQA. cache: (k, v) ring buffers or None (train).

        In decode, ``pos`` is per-sequence (B,) so continuous batching can mix
        sequences at different depths.
        """
        cfg = self.cfg
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        positions = (jnp.arange(x.shape[1], dtype=jnp.int32)
                     if cache is None else pos[:, None].astype(jnp.int32))
        q, k, v = qkv(cfg, p["attn"], xn, positions)
        new_cache = None
        if cache is None:
            o = chunked_attention(q, k, v, causal=True, window=cfg.local_window,
                                  q_offset=0)
        else:
            ck, cv = cache
            T = kv_cache_len(ck)
            slot = (pos % T).astype(jnp.int32)
            ck = kv_cache_update(ck, k, slot)
            cv = kv_cache_update(cv, v, slot)
            o = chunked_attention(q, kv_cast(ck, x.dtype), kv_cast(cv, x.dtype),
                                  causal=True, window=cfg.local_window,
                                  q_offset=pos, kv_positions=pos_ids,
                                  chunk_kv=min(1024, T))
            new_cache = (ck, cv)
        x = x + dense(o, p["attn"]["w_o"], "bshe,hed->bsd")
        x = x + gated_mlp(rms_norm(x, p["ln2"], cfg.norm_eps),
                          p["mlp"]["wi_gate"], p["mlp"]["wi_up"], p["mlp"]["wo"])
        return constrain(x, "act_batch", "act_seq", "act_embed"), new_cache

    # -- train forward ----------------------------------------------------------
    def forward(self, params, tokens):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]
        x = constrain(x, "act_batch", "act_seq", "act_embed")

        def group_body(carry, gp):
            h = optimization_barrier(carry)
            gp = mod.constrain_tree(gp, self._group_specs())
            h, _ = self._rec_layer(gp["rec1"], h, None)
            h, _ = self._rec_layer(gp["rec2"], h, None)
            h, _ = self._attn_layer(gp["attn"], h, None, None, None)
            return h, None

        fn = group_body
        if cfg.remat != "none":
            fn = jax.checkpoint(group_body, prevent_cse=False)
        x, _ = jax.lax.scan(fn, x, params["groups"])
        for tp in params["tail"]:
            x, _ = self._rec_layer(tp, x, None)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["embed"].T, "bsd,dv->bsv")
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(
                logits.astype(jnp.float32) / cfg.logit_softcap).astype(logits.dtype)
        return constrain(logits, "act_batch", "act_seq", "act_vocab")

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                            batch.get("loss_mask"))

    # -- serving ------------------------------------------------------------------
    def cache_len(self, max_len: int) -> int:
        return aligned_cache_len(min(max_len, self.cfg.local_window))

    def _rec_state_zero(self, batch: int):
        cfg = self.cfg
        return {
            "h": jnp.zeros((batch, cfg.d_model), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_model),
                              self.cdtype),
        }

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        T = self.cache_len(max_len)
        G = self.n_groups
        kv = (batch, T, cfg.num_kv_heads, cfg.resolved_head_dim)
        stack = lambda t: jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape), t)
        return {
            "rec1": stack(self._rec_state_zero(batch)),
            "rec2": stack(self._rec_state_zero(batch)),
            "k": kv_cache_init((G,) + kv, self.cdtype),
            "v": kv_cache_init((G,) + kv, self.cdtype),
            "tail": [self._rec_state_zero(batch) for _ in range(self.n_tail)],
            "pos_ids": jnp.full((batch, T), -1, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self):
        rec = {"h": ("layers", "act_batch", "act_embed"),
               "conv": ("layers", "act_batch", None, "act_embed")}
        kv = kv_cache_axes(
            ("layers", "act_batch", "cache_seq", "cache_kv", "cache_hd"))
        return {
            "rec1": rec, "rec2": rec, "k": kv, "v": kv,
            "tail": [{"h": ("act_batch", "act_embed"),
                      "conv": ("act_batch", None, "act_embed")}
                     for _ in range(self.n_tail)],
            "pos_ids": ("act_batch", "cache_seq"), "pos": ("act_batch",),
        }

    def prefill(self, params, batch, max_len=None, full_logits=False):
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        T = self.cache_len(max(max_len or S, S))
        x = params["embed"].astype(self.cdtype)[tokens]

        def store(k):
            return kv_cache_store(k.astype(self.cdtype), S, T)

        def group_body(carry, gp):
            h = carry
            gp = mod.constrain_tree(gp, self._group_specs())
            h, s1 = self._rec_layer(gp["rec1"], h, self._rec_state_zero(B))
            h, s2 = self._rec_layer(gp["rec2"], h, self._rec_state_zero(B))
            # attn with window cache from last T positions
            xn = rms_norm(h, gp["attn"]["ln1"], cfg.norm_eps)
            positions = jnp.arange(S, dtype=jnp.int32)
            q, k, v = qkv(cfg, gp["attn"]["attn"], xn, positions)
            o = chunked_attention(q, k, v, causal=True, window=cfg.local_window,
                                  q_offset=0)
            h = h + dense(o, gp["attn"]["attn"]["w_o"], "bshe,hed->bsd")
            h = h + gated_mlp(rms_norm(h, gp["attn"]["ln2"], cfg.norm_eps),
                              gp["attn"]["mlp"]["wi_gate"],
                              gp["attn"]["mlp"]["wi_up"],
                              gp["attn"]["mlp"]["wo"])
            return h, (s1, s2, store(k), store(v))

        x, (s1, s2, ck, cv) = jax.lax.scan(group_body, x, params["groups"])
        tail_states = []
        for tp in params["tail"]:
            x, st = self._rec_layer(tp, x, self._rec_state_zero(B))
            tail_states.append(st)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x if full_logits else x[:, -1:], params["embed"].T,
                       "bsd,dv->bsv")
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(
                logits.astype(jnp.float32) / cfg.logit_softcap).astype(logits.dtype)
        cache = {
            "rec1": s1, "rec2": s2, "k": ck, "v": cv, "tail": tail_states,
            "pos_ids": ring_position_ids(B, S, T),
            "pos": jnp.full((B,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]      # (B,1,D)
        pos = cache["pos"]                                   # (B,)
        T = kv_cache_len(cache["k"])
        slot = (pos % T).astype(jnp.int32)
        pos_ids = ring_cache_update(cache["pos_ids"], pos[:, None], slot)

        def group_body(carry, xs):
            h = carry
            gp, s1, s2, ck, cv = xs
            gp = mod.constrain_tree(gp, self._group_specs())
            h, s1n = self._rec_layer(gp["rec1"], h, s1)
            h, s2n = self._rec_layer(gp["rec2"], h, s2)
            h, kv_new = self._attn_layer(gp["attn"], h, (ck, cv), pos, pos_ids)
            return h, (s1n, s2n, kv_new[0], kv_new[1])

        x, (s1, s2, ck, cv) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["rec1"], cache["rec2"], cache["k"],
             cache["v"]))
        tail_states = []
        for tp, st in zip(params["tail"], cache["tail"]):
            x, stn = self._rec_layer(tp, x, st)
            tail_states.append(stn)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["embed"].T, "bsd,dv->bsv")
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(
                logits.astype(jnp.float32) / cfg.logit_softcap).astype(logits.dtype)
        new_cache = {
            "rec1": s1, "rec2": s2, "k": ck, "v": cv, "tail": tail_states,
            "pos_ids": pos_ids, "pos": pos + 1,
        }
        return logits, new_cache


# ===========================================================================
# XLSTMLM — interleaved mLSTM / sLSTM blocks (12 layers, unrolled)
# ===========================================================================
class XLSTMLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dt(cfg.param_dtype)
        self.cdtype = _dt(cfg.compute_dtype)

    def _is_slstm(self, i: int) -> bool:
        k = self.cfg.slstm_every
        return k > 0 and (i + 1) % k == 0

    def param_specs(self):
        cfg = self.cfg
        blocks = []
        for i in range(cfg.num_layers):
            ln = mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",))
            if self._is_slstm(i):
                blocks.append({"ln": ln, "slstm": slstm_param_specs(cfg, self.dtype)})
            else:
                blocks.append({"ln": ln, "mlstm": mlstm_param_specs(cfg, self.dtype)})
        return {
            "embed": mod.spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              self.dtype),
            "blocks": blocks,
            "final_norm": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
        }

    def _apply_block(self, i, p, x, state):
        cfg = self.cfg
        xn = rms_norm(x, p["ln"], cfg.norm_eps)
        if self._is_slstm(i):
            y, st = slstm_scan(cfg, p["slstm"], xn, state)
        else:
            if x.shape[1] == 1 and state is not None:
                y, st = mlstm_step(cfg, p["mlstm"], xn, state)
            else:
                y, st = mlstm_chunked(cfg, p["mlstm"], xn, state,
                                      chunk=min(256, x.shape[1]))
        return constrain(x + y, "act_batch", "act_seq", "act_embed"), st

    def forward(self, params, tokens):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]
        for i, p in enumerate(params["blocks"]):
            blk = lambda pp, xx, i=i: self._apply_block(i, pp, xx, None)[0]
            if cfg.remat != "none":
                blk = jax.checkpoint(blk, prevent_cse=False)
            x = blk(p, x)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return dense(x, params["embed"].T, "bsd,dv->bsv")

    def loss(self, params, batch):
        logits = self.forward(params, batch["tokens"])
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                            batch.get("loss_mask"))

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        states = []
        for i in range(cfg.num_layers):
            if self._is_slstm(i):
                states.append(init_slstm_state(cfg, batch))
            else:
                states.append(init_mlstm_state(cfg, batch))
        return {"blocks": states, "pos": jnp.zeros((batch,), jnp.int32)}

    def cache_logical_axes(self):
        cfg = self.cfg
        states = []
        for i in range(cfg.num_layers):
            if self._is_slstm(i):
                states.append({k: ("act_batch", "act_embed")
                               for k in ("c", "n", "m", "h")})
            else:
                states.append({
                    "C": ("act_batch", "act_heads", "act_hd", None),
                    "n": ("act_batch", "act_heads", "act_hd"),
                    "m": ("act_batch", "act_heads"),
                })
        return {"blocks": states, "pos": ("act_batch",)}

    def prefill(self, params, batch, max_len=None):
        # recurrent state is O(1) in sequence length: max_len is irrelevant
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"].astype(self.cdtype)[tokens]
        states = []
        for i, p in enumerate(params["blocks"]):
            init = (init_slstm_state(cfg, x.shape[0]) if self._is_slstm(i)
                    else init_mlstm_state(cfg, x.shape[0]))
            x, st = self._apply_block(i, p, x, init)
            states.append(st)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x[:, -1:], params["embed"].T, "bsd,dv->bsv")
        return logits, {"blocks": states,
                        "pos": jnp.full((tokens.shape[0],), tokens.shape[1],
                                        jnp.int32)}

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]
        states = []
        for i, p in enumerate(params["blocks"]):
            x, st = self._apply_block(i, p, x, cache["blocks"][i])
            states.append(st)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = dense(x, params["embed"].T, "bsd,dv->bsv")
        return logits, {"blocks": states, "pos": cache["pos"] + 1}
