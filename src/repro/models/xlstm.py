"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

mLSTM: matrix memory C_t = f_t C_{t-1} + i_t v_t k_t^T, parallelized
chunkwise (intra-chunk quadratic + inter-chunk recurrent state) so train /
prefill memory stays O(chunk^2) — same data-movement philosophy as the
chunked attention path. sLSTM: scalar memory, inherently sequential (thesis
of the xLSTM paper) -> lax.scan over time.

d_ff = 0 in the assigned config: projections live inside the blocks; there is
no separate FFN.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import dense, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_param_specs(cfg: ModelConfig, dtype: Any) -> Dict[str, mod.ParamSpec]:
    d, h = cfg.d_model, cfg.num_heads
    dh = d // h
    return {
        "w_q": mod.spec((d, h, dh), ("embed", "heads", "head_dim"), dtype),
        "w_k": mod.spec((d, h, dh), ("embed", "heads", "head_dim"), dtype),
        "w_v": mod.spec((d, h, dh), ("embed", "heads", "head_dim"), dtype),
        "w_i": mod.spec((d, h), ("embed", "heads"), dtype),
        "w_f": mod.spec((d, h), ("embed", "heads"), dtype),
        "b_i": mod.spec((h,), ("heads",), jnp.float32, ("zeros",)),
        "b_f": mod.spec((h,), ("heads",), jnp.float32, ("ones",)),
        "w_gate": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_out": mod.spec((d, d), ("mlp", "embed"), dtype),
        "norm": mod.spec((d,), (None,), jnp.float32, ("ones",)),
    }


def _mlstm_gates(p, x):
    """i, f gate pre-activations in fp32. x: (B,S,D) -> (B,S,H)."""
    i = dense(x, p["w_i"], "bsd,dh->bsh").astype(jnp.float32) + p["b_i"]
    f = dense(x, p["w_f"], "bsd,dh->bsh").astype(jnp.float32) + p["b_f"]
    return i, f


def mlstm_chunked(cfg: ModelConfig, p, x: jax.Array,
                  state: Dict[str, jax.Array] | None = None,
                  chunk: int = 256) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunkwise-parallel mLSTM. x: (B,S,D) -> (y, state).

    State: C (B,H,Dk,Dv), n (B,H,Dk), m (B,H) — log-space stabilized.
    """
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    c = min(chunk, S)
    assert S % c == 0
    nchunk = S // c

    q = dense(x, p["w_q"], "bsd,dhe->bshe") / (dh ** 0.5)
    k = dense(x, p["w_k"], "bsd,dhe->bshe")
    v = dense(x, p["w_v"], "bsd,dhe->bshe")
    i_pre, f_pre = _mlstm_gates(p, x)                       # (B,S,H)
    log_f = -jax.nn.softplus(-f_pre)                        # log sigmoid(f)
    log_i = i_pre                                           # i = exp(i_pre)

    qg = q.reshape(B, nchunk, c, H, dh)
    kg = k.reshape(B, nchunk, c, H, dh)
    vg = v.reshape(B, nchunk, c, H, dh)
    lfg = log_f.reshape(B, nchunk, c, H)
    lig = log_i.reshape(B, nchunk, c, H)

    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def chunk_step(carry, j):
        C, n, m = carry
        qc = qg[:, j].astype(jnp.float32)                   # (B,c,H,dh)
        kc = kg[:, j].astype(jnp.float32)
        vc = vg[:, j].astype(jnp.float32)
        lf = lfg[:, j]                                      # (B,c,H)
        li = lig[:, j]
        csum = jnp.cumsum(lf, axis=1)                       # inclusive
        total = csum[:, -1]                                 # (B,H)
        # decay from chunk start to t (exclusive of t's own f? standard:
        # b_t = csum_t includes f_t; state contribution decayed by csum_t)
        # intra-chunk log weights: w[t,s] = csum_t - csum_s + li_s  (s <= t)
        dmat = csum[:, :, None, :] - csum[:, None, :, :]    # (B,t,s,H)
        logw = dmat + li[:, None, :, :]
        tri = jnp.tril(jnp.ones((c, c), bool))
        logw = jnp.where(tri[None, :, :, None], logw, -1e30)
        # inter-chunk: q_t reads state decayed by csum_t, with stabilizer m
        m_inter = csum + m[:, None, :]                      # (B,t,H)
        m_intra = logw.max(axis=2)                          # (B,t,H)
        m_t = jnp.maximum(m_inter, m_intra)
        w = jnp.exp(logw - m_t[:, :, None, :])              # (B,t,s,H)
        scores = jnp.einsum("bthe,bshe->btsh", qc, kc)      # (B,t,s,H)
        num_intra = jnp.einsum("btsh,btsh,bshe->bthe", scores, w, vc)
        den_intra = jnp.einsum("btsh,btsh,bsh->bth", scores, w,
                               jnp.ones((B, c, H), jnp.float32))
        # denominator uses k-normalizer: den = q . n-style sum of w * (q.k)
        inter_scale = jnp.exp(m_inter - m_t)                # (B,t,H)
        num_inter = jnp.einsum("bthe,bhef->bthf", qc, C) * inter_scale[..., None]
        den_inter = jnp.einsum("bthe,bhe->bth", qc, n) * inter_scale
        num = num_intra + num_inter
        den = den_intra + den_inter
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))      # xLSTM max(|n|,1) stab
        y = num / den[..., None]
        # state update: C' = exp(total + m - m') C + sum_s exp(total-csum_s+li_s - m') k_s v_s
        m_new = jnp.maximum(total + m, (total[:, None] - csum + li).max(axis=1))
        sk = jnp.exp(total[:, None] - csum + li - m_new[:, None])  # (B,s,H)
        C_new = (
            jnp.exp(total + m - m_new)[:, :, None, None] * C
            + jnp.einsum("bsh,bshe,bshf->bhef", sk, kc, vc)
        )
        n_new = (
            jnp.exp(total + m - m_new)[:, :, None] * n
            + jnp.einsum("bsh,bshe->bhe", sk, kc)
        )
        return (C_new, n_new, m_new), y.astype(x.dtype)

    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0),
                                 jnp.arange(nchunk, dtype=jnp.int32))
    # ys: (nchunk, B, c, H, dh)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dh).reshape(B, S, D)
    gate = jax.nn.silu(dense(x, p["w_gate"], "bsd,de->bse"))
    y = rms_norm(y, p["norm"], 1e-6) * gate
    out = dense(y, p["w_out"], "bse,ed->bsd")
    return out, {"C": C, "n": n, "m": m}


def mlstm_step(cfg: ModelConfig, p, x: jax.Array,
               state: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode step (B,1,D) with O(1) matrix-memory update."""
    B, _, D = x.shape
    H = cfg.num_heads
    dh = D // H
    q = dense(x, p["w_q"], "bsd,dhe->bshe")[:, 0].astype(jnp.float32) / (dh ** 0.5)
    k = dense(x, p["w_k"], "bsd,dhe->bshe")[:, 0].astype(jnp.float32)
    v = dense(x, p["w_v"], "bsd,dhe->bshe")[:, 0].astype(jnp.float32)
    i_pre, f_pre = _mlstm_gates(p, x)
    li = i_pre[:, 0]                                        # (B,H)
    lf = -jax.nn.softplus(-f_pre[:, 0])
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    C = jnp.exp(lf + m - m_new)[..., None, None] * C + jnp.exp(li - m_new)[
        ..., None, None
    ] * jnp.einsum("bhe,bhf->bhef", k, v)
    n = jnp.exp(lf + m - m_new)[..., None] * n + jnp.exp(li - m_new)[..., None] * k
    num = jnp.einsum("bhe,bhef->bhf", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", q, n)), jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(B, 1, D).astype(x.dtype)
    gate = jax.nn.silu(dense(x, p["w_gate"], "bsd,de->bse"))
    y = rms_norm(y, p["norm"], 1e-6) * gate
    out = dense(y, p["w_out"], "bse,ed->bsd")
    return out, {"C": C, "n": n, "m": m_new}


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    H = cfg.num_heads
    dh = cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_param_specs(cfg: ModelConfig, dtype: Any) -> Dict[str, mod.ParamSpec]:
    d, h = cfg.d_model, cfg.num_heads
    return {
        "w_z": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_i": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_f": mod.spec((d, d), ("embed", "mlp"), dtype),
        "w_o": mod.spec((d, d), ("embed", "mlp"), dtype),
        "r_z": mod.spec((d,), ("mlp",), jnp.float32, ("zeros",)),
        "r_i": mod.spec((d,), ("mlp",), jnp.float32, ("zeros",)),
        "r_f": mod.spec((d,), ("mlp",), jnp.float32, ("zeros",)),
        "r_o": mod.spec((d,), ("mlp",), jnp.float32, ("zeros",)),
        "w_out": mod.spec((d, d), ("mlp", "embed"), dtype),
        "norm": mod.spec((d,), (None,), jnp.float32, ("ones",)),
    }


def _slstm_cell(p, zi, ii, fi, oi, state):
    """One timestep. pre-activations (B,D) fp32; state (c,n,m,h)."""
    c, n, m, h = state
    z = jnp.tanh(zi + p["r_z"] * h)
    o = jax.nn.sigmoid(oi + p["r_o"] * h)
    log_i = ii + p["r_i"] * h
    log_f = -jax.nn.softplus(-(fi + p["r_f"] * h))          # log sigmoid
    m_new = jnp.maximum(log_f + m, log_i)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def slstm_scan(cfg: ModelConfig, p, x: jax.Array,
               state=None) -> Tuple[jax.Array, Any]:
    """Sequential sLSTM over time. x: (B,S,D)."""
    B, S, D = x.shape
    zi = dense(x, p["w_z"], "bsd,de->bse").astype(jnp.float32)
    ii = dense(x, p["w_i"], "bsd,de->bse").astype(jnp.float32)
    fi = dense(x, p["w_f"], "bsd,de->bse").astype(jnp.float32)
    oi = dense(x, p["w_o"], "bsd,de->bse").astype(jnp.float32)
    if state is None:
        state = init_slstm_state(cfg, B)
    st = (state["c"], state["n"], state["m"], state["h"])

    def step(carry, t):
        new = _slstm_cell(p, zi[:, t], ii[:, t], fi[:, t], oi[:, t], carry)
        return new, new[3]

    st, hs = jax.lax.scan(step, st, jnp.arange(S, dtype=jnp.int32))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)              # (B,S,D)
    y = rms_norm(y, p["norm"], 1e-6)
    out = dense(y, p["w_out"], "bse,ed->bsd")
    return out, {"c": st[0], "n": st[1], "m": st[2], "h": st[3]}


def init_slstm_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "m": z(), "h": z()}
