"""Decoder-only transformer LM (dense / MoE / VLM families) + model dispatch.

One homogeneous block stack, scanned (``jax.lax.scan``) over stacked params so
HLO size and compile time are O(1) in depth; KV-cache decode path for
serving. Hybrid (RG-LRU), SSM (xLSTM) and enc-dec (audio) families live in
sibling modules and share the same Model protocol:

    param_specs() -> spec pytree
    loss(params, batch) -> scalar
    prefill(params, batch) -> (last_logits, cache)
    decode_step(params, cache, tokens) -> (logits, cache)
    init_cache(batch, max_len) -> cache pytree
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.configs.base import ModelConfig
from repro.core.mimdram import constrain
from repro.models import module as mod
from repro.models.layers import (aligned_cache_len, chunked_attention, dense,
                                 gated_mlp, kv_cache_axes, kv_cache_init,
                                 kv_cache_len, kv_cache_store,
                                 kv_cache_update, kv_cast, ring_cache_update,
                                 ring_position_ids, rms_norm, rope,
                                 softmax_xent, stack_trees)
from repro.models.moe import moe_ffn, moe_param_specs


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# Shared block pieces
# ---------------------------------------------------------------------------
def attn_param_specs(cfg: ModelConfig, dtype) -> Dict[str, mod.ParamSpec]:
    d, hq, hkv, dh = (cfg.d_model, cfg.tp_pad_heads or cfg.num_heads,
                      cfg.num_kv_heads, cfg.resolved_head_dim)
    return {
        "w_q": mod.spec((d, hq, dh), ("embed", "heads", "head_dim"), dtype),
        "w_k": mod.spec((d, hkv, dh), ("embed", "kv", "head_dim"), dtype),
        "w_v": mod.spec((d, hkv, dh), ("embed", "kv", "head_dim"), dtype),
        "w_o": mod.spec((hq, dh, d), ("heads", "head_dim", "embed"), dtype,
                        ("normal", 0)),
    }


def mlp_param_specs(cfg: ModelConfig, dtype) -> Dict[str, mod.ParamSpec]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": mod.spec((d, f), ("embed", "mlp"), dtype),
        "wi_up": mod.spec((d, f), ("embed", "mlp"), dtype),
        "wo": mod.spec((f, d), ("mlp", "embed"), dtype),
    }


def qkv(cfg: ModelConfig, p, xn: jax.Array, positions) -> Tuple[jax.Array, ...]:
    q = dense(xn, p["w_q"], "bsd,dhe->bshe")
    k = dense(xn, p["w_k"], "bsd,dhe->bshe")
    v = dense(xn, p["w_v"], "bsd,dhe->bshe")
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "act_batch", "act_seq", "act_heads", "act_hd")
    k = constrain(k, "act_batch", "act_seq", "act_kv", "act_hd")
    return q, k, v


def attn_out(p, o: jax.Array) -> jax.Array:
    return dense(o, p["w_o"], "bshe,hed->bsd")


# ---------------------------------------------------------------------------
# TransformerLM
# ---------------------------------------------------------------------------
class TransformerLM:
    """dense / moe / vlm families."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = _dt(cfg.param_dtype)
        self.cdtype = _dt(cfg.compute_dtype)

    # -- specs ---------------------------------------------------------------
    def block_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        s: Dict[str, Any] = {
            "ln1": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "ln2": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "attn": attn_param_specs(cfg, self.dtype),
        }
        if cfg.num_experts > 0:
            s["moe"] = moe_param_specs(cfg, self.dtype)
        else:
            s["mlp"] = mlp_param_specs(cfg, self.dtype)
        return s

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": mod.spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                              self.dtype),
            "final_norm": mod.spec((cfg.d_model,), (None,), jnp.float32, ("ones",)),
            "blocks": mod.stack_tree(self.block_specs(), cfg.num_layers),
        }
        if not cfg.tie_embeddings:
            specs["head"] = mod.spec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), self.dtype)
        return specs

    # -- one block -----------------------------------------------------------
    def _block(self, p, x, positions, *, window, block_skip=False):
        cfg = self.cfg
        # barrier: stops XLA promoting the scan-saved bf16 residual stack to
        # f32 via convert motion (observed 2x activation memory otherwise)
        x = optimization_barrier(x)
        p = mod.constrain_tree(p, self.block_specs())
        xn = rms_norm(x, p["ln1"], cfg.norm_eps)
        q, k, v = qkv(cfg, p["attn"], xn, positions)
        o = chunked_attention(q, k, v, causal=True, window=window, q_offset=0,
                              chunk_q=cfg.attn_chunk_q,
                              chunk_kv=cfg.attn_chunk_kv,
                              block_skip=cfg.attn_block_skip or block_skip)
        x = x + dense(o, p["attn"]["w_o"], "bshe,hed->bsd")
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        xn2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.num_experts > 0:
            y = moe_ffn(cfg, p["moe"], xn2)
        else:
            y = gated_mlp(xn2, p["mlp"]["wi_gate"], p["mlp"]["wi_up"],
                          p["mlp"]["wo"])
        x = x + y
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        return x

    # -- full-sequence forward (train / prefill) ------------------------------
    def forward(self, params, tokens: jax.Array,
                patch_embeds: Optional[jax.Array] = None):
        cfg = self.cfg
        x = params["embed"].astype(self.cdtype)[tokens]
        if patch_embeds is not None:
            x = jnp.concatenate([patch_embeds.astype(self.cdtype), x], axis=1)
        B, S, _ = x.shape
        x = constrain(x, "act_batch", "act_seq", "act_embed")
        positions = jnp.arange(S, dtype=jnp.int32)
        window = cfg.sliding_window if cfg.attention_kind == "sliding" else 0

        def body(carry, layer_p):
            return self._block(layer_p, carry, positions, window=window), None

        block_fn = body
        if cfg.remat != "none":
            block_fn = jax.checkpoint(body, prevent_cse=False)
        if cfg.scan_layers:
            x, _ = jax.lax.scan(block_fn, x, params["blocks"])
        else:
            for i in range(cfg.num_layers):
                layer_p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                x, _ = block_fn(x, layer_p)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["head"])
        logits = dense(x, head, "bsd,dv->bsv")
        logits = constrain(logits, "act_batch", "act_seq", "act_vocab")
        return logits

    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        logits = self.forward(params, tokens, batch.get("patch_embeds"))
        if "patch_embeds" in batch and batch["patch_embeds"] is not None:
            # loss only over text region (after the patch prefix)
            P = batch["patch_embeds"].shape[1]
            logits = logits[:, P:]
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        return softmax_xent(logits[:, :-1], labels[:, 1:],
                            None if mask is None else mask[:, 1:])

    # -- serving ---------------------------------------------------------------
    def cache_len(self, max_len: int) -> int:
        cfg = self.cfg
        if cfg.attention_kind == "sliding" and cfg.sliding_window > 0:
            return aligned_cache_len(min(max_len, cfg.sliding_window))
        return aligned_cache_len(max_len)

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        T = self.cache_len(max_len)
        kv = (batch, T, cfg.num_kv_heads, cfg.resolved_head_dim)
        L = cfg.num_layers
        return {
            "k": kv_cache_init((L,) + kv, self.cdtype),
            "v": kv_cache_init((L,) + kv, self.cdtype),
            "pos_ids": jnp.full((batch, T), -1, jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }

    def cache_logical_axes(self) -> Dict[str, Any]:
        kv = kv_cache_axes(
            ("layers", "act_batch", "cache_seq", "cache_kv", "cache_hd"))
        return {"k": kv, "v": kv, "pos_ids": ("act_batch", "cache_seq"),
                "pos": ("act_batch",)}

    def prefill(self, params, batch, max_len: Optional[int] = None,
                full_logits: bool = False) -> Tuple[jax.Array, Dict[str, Any]]:
        """Run the full prompt, return last-token logits + filled cache.

        With ``max_len`` the cache is pre-sized for ``max_len`` total positions
        (ring-aligned so decode's ``pos % T`` writes land on the right slots)
        — prefill -> decode involves zero cache copies or repads.
        ``full_logits=True`` returns logits for every position instead of the
        last one (the paged engine right-pads prompts to a bucket length and
        reads the logits at the true prompt end).
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        patch = batch.get("patch_embeds")
        x = params["embed"].astype(self.cdtype)[tokens]
        if patch is not None:
            x = jnp.concatenate([patch.astype(self.cdtype), x], axis=1)
        B, S, _ = x.shape
        T = self.cache_len(max(max_len or S, S))
        positions = jnp.arange(S, dtype=jnp.int32)
        window = cfg.sliding_window if cfg.attention_kind == "sliding" else 0

        def store(k):
            return kv_cache_store(k.astype(self.cdtype), S, T)

        def body(carry, layer_p):
            h = carry
            layer_p = mod.constrain_tree(layer_p, self.block_specs())
            xn = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
            q, k, v = qkv(cfg, layer_p["attn"], xn, positions)
            o = chunked_attention(q, k, v, causal=True, window=window,
                                  q_offset=0, chunk_q=cfg.attn_chunk_q,
                                  chunk_kv=cfg.attn_chunk_kv,
                                  block_skip=cfg.attn_block_skip)
            h = h + dense(o, layer_p["attn"]["w_o"], "bshe,hed->bsd")
            xn2 = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
            if cfg.num_experts > 0:
                y = moe_ffn(cfg, layer_p["moe"], xn2)
            else:
                y = gated_mlp(xn2, layer_p["mlp"]["wi_gate"],
                              layer_p["mlp"]["wi_up"], layer_p["mlp"]["wo"])
            h = h + y
            return h, (store(k), store(v))

        if cfg.scan_layers:
            x, (ck, cv) = jax.lax.scan(body, x, params["blocks"])
        else:
            ks, vs = [], []
            for i in range(cfg.num_layers):
                layer_p = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                x, (k1, v1) = body(x, layer_p)
                ks.append(k1)
                vs.append(v1)
            ck, cv = stack_trees(ks), stack_trees(vs)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["head"])
        logits = dense(x if full_logits else x[:, -1:], head, "bsd,dv->bsv")
        cache = {
            "k": ck, "v": cv,
            "pos_ids": ring_position_ids(B, S, T),
            "pos": jnp.full((B,), S, jnp.int32),
        }
        return logits, cache

    def decode_step(self, params, cache, tokens: jax.Array,
                    layers: Optional[int] = None):
        """tokens: (B, S). Appends S tokens per row; returns their logits.

        Positions are per-sequence (``pos``: (B,)) so continuous batching can
        host sequences at different depths in one cache. S == 1 is the
        per-token decode step; S > 1 is a speculative verify block (the fed
        token plus k drafts), written at consecutive slots and attended
        causally within the block via the position masks. ``layers`` (static)
        truncates the forward to the first N transformer blocks — the
        layer-skip self-drafting pass of speculative decoding — updating only
        those layers' cache entries (the verify pass overwrites them with
        identical values, so partial-layer writes never leak).
        """
        cfg = self.cfg
        S = tokens.shape[1]
        x = params["embed"].astype(self.cdtype)[tokens]          # (B,S,D)
        pos = cache["pos"]                                       # (B,)
        T = kv_cache_len(cache["k"])
        window = cfg.sliding_window if cfg.attention_kind == "sliding" else 0
        if S == 1:
            slot = (pos % T).astype(jnp.int32)                   # (B,)
            positions = pos[:, None].astype(jnp.int32)           # (B, 1)
            pos_ids = ring_cache_update(cache["pos_ids"], pos[:, None], slot)
        else:
            block_pos = pos[:, None] + jnp.arange(S, dtype=pos.dtype)
            slot = (block_pos % T).astype(jnp.int32)             # (B, S)
            positions = block_pos.astype(jnp.int32)
            pos_ids = ring_cache_update(cache["pos_ids"], block_pos, slot)

        def body(carry, xs):
            h = carry
            layer_p, ck, cv = xs
            layer_p = mod.constrain_tree(layer_p, self.block_specs())
            xn = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
            q, k, v = qkv(cfg, layer_p["attn"], xn, positions)
            ck = kv_cache_update(ck, k, slot)
            cv = kv_cache_update(cv, v, slot)
            o = chunked_attention(
                q, kv_cast(ck, h.dtype), kv_cast(cv, h.dtype), causal=True,
                window=window, q_offset=pos, kv_positions=pos_ids,
                chunk_kv=min(1024, T))
            h = h + dense(o, layer_p["attn"]["w_o"], "bshe,hed->bsd")
            xn2 = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
            if cfg.num_experts > 0:
                y = moe_ffn(cfg, layer_p["moe"], xn2)
            else:
                y = gated_mlp(xn2, layer_p["mlp"]["wi_gate"],
                              layer_p["mlp"]["wi_up"], layer_p["mlp"]["wo"])
            return h + y, (ck, cv)

        tm = jax.tree_util.tree_map
        blocks, ck0, cv0 = params["blocks"], cache["k"], cache["v"]
        if layers is not None:
            blocks = tm(lambda a: a[:layers], blocks)
            ck0 = tm(lambda a: a[:layers], ck0)
            cv0 = tm(lambda a: a[:layers], cv0)
        if cfg.scan_layers:
            x, (ck, cv) = jax.lax.scan(body, x, (blocks, ck0, cv0))
        else:
            n_layers = cfg.num_layers if layers is None else layers
            ks, vs = [], []
            for i in range(n_layers):
                xs = tm(lambda a: a[i], (blocks, ck0, cv0))
                x, (k1, v1) = body(x, xs)
                ks.append(k1)
                vs.append(v1)
            ck, cv = stack_trees(ks), stack_trees(vs)
        if layers is not None:
            ck = tm(lambda f, p: f.at[:layers].set(p), cache["k"], ck)
            cv = tm(lambda f, p: f.at[:layers].set(p), cache["v"], cv)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        head = (params["embed"].T if cfg.tie_embeddings else params["head"])
        logits = dense(x, head, "bsd,dv->bsv")
        new_cache = {"k": ck, "v": cv, "pos_ids": pos_ids,
                     "pos": pos + jnp.asarray(S, pos.dtype)}
        return logits, new_cache


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "hybrid":
        from repro.models.recurrent_lm import GriffinLM
        return GriffinLM(cfg)
    if cfg.family == "ssm":
        from repro.models.recurrent_lm import XLSTMLM
        return XLSTMLM(cfg)
    if cfg.family == "audio":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
