from repro.models.model import TransformerLM, build_model
from repro.models.module import (abstract_params, count_params, init_params,
                                 param_bytes, param_pspecs)

__all__ = [
    "build_model",
    "TransformerLM",
    "init_params",
    "abstract_params",
    "param_pspecs",
    "count_params",
    "param_bytes",
]
