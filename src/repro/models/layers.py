"""Shared layers: RMSNorm, RoPE, gated MLP, and the attention dispatch layer.

``chunked_attention`` is the single attention entry point for every model
family and the serving engine. It dispatches between two backends
(``REPRO_ATTN_IMPL=pallas|jnp|auto``; auto = compiled Pallas on TPU, jnp
elsewhere):

* **pallas** — the ``repro.kernels.flash_attention`` TPU kernels: GQA-native
  prefill/train forward with a recompute-based custom VJP, and a
  decode-specialized kernel streaming the ring KV cache.
* **jnp** — the chunked online-softmax implementation below (same flash
  structure in pure jnp); the oracle the Pallas path is tested against.

Both keep the working set per step at one (q-chunk x kv-chunk) tile — the
HBM->VMEM data-movement-minimization analogue of processing-using-memory.

The decode hot path additionally supports a Proteus-quantized KV cache
(``REPRO_KV_QUANT``, :class:`QKVCache` below): k/v may arrive as block-scaled
int8 / packed-int4 codes + per-row scales, consumed directly by the Pallas
decode kernel (in-kernel dequant) and dequantized up front on the jnp path.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mimdram import constrain
from repro.core.proteus import required_bits_float
from repro.kernels.common import (attn_impl, kv_page_size, kv_quant_mode,
                                  pack_int4, pad_axis, pad_positions,
                                  unpack_int4)
from repro.kernels.flash_attention.ops import (flash_attention_gqa_fwd,
                                               flash_decode,
                                               flash_decode_paged,
                                               flash_decode_paged_quant,
                                               flash_decode_quant,
                                               paged_decode_supported)

# Pallas decode kernel: the whole (G, S) query block stays VMEM-resident
# across the kv stream, so the positional path only routes to it while the
# q-block row count is small; beyond this, forced-pallas calls fall back to
# the jnp path (with a trace-time warning).
PALLAS_DECODE_MAX_Q_ROWS = 1024

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Basic ops
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(dt)


def dense(x: jax.Array, w: jax.Array, subscripts: str) -> jax.Array:
    """einsum in compute dtype with fp32 accumulation."""
    y = jnp.einsum(subscripts, x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype)


def gated_mlp(x: jax.Array, wi_gate: jax.Array, wi_up: jax.Array,
              wo: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x W_g) * x W_u) W_o; activations TP-sharded on d_ff."""
    g = dense(x, wi_gate, "bsd,df->bsf")
    u = dense(x, wi_up, "bsd,df->bsf")
    h = jax.nn.silu(g) * u
    h = constrain(h, "act_batch", "act_seq", "act_ff")
    return dense(h, wo, "bsf,fd->bsd")


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    # broadcast to (..., S, 1, half) over heads
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


def slot_isfinite(logits: jax.Array) -> jax.Array:
    """Per-slot finite guard for the fused decode scan: ``(B, ..., V)``
    logits -> ``(B,)`` bool, True iff every logit the slot produced this
    step is finite. Slots are independent through the whole decode stack
    (per-sequence positions, per-slot cache rows), so a non-finite row
    indicts exactly one slot and the engine can quarantine it without
    touching the rest of the batch."""
    B = logits.shape[0]
    return jnp.all(jnp.isfinite(logits.reshape(B, -1)), axis=-1)


def ring_cache_update(cache: jax.Array, new: jax.Array,
                      slot: jax.Array) -> jax.Array:
    """Write ``new`` (B, S, ...) into ``cache`` (B, T, ...) at per-row slots.

    Each sequence in the batch carries its own write position (continuous
    batching: slots are refilled independently). ``slot`` is (B,) for the
    per-token decode path (S == 1 — a per-row dynamic_update_slice, the
    original fused-decode write) or (B, S) explicit slots for a speculative
    verify block (a scatter; block positions may wrap mod T, and the S
    consecutive slots are distinct as long as S <= T).
    """
    s = slot.astype(jnp.int32)
    if new.shape[1] == 1 and s.ndim == 1:
        zeros = (jnp.int32(0),) * (cache.ndim - 2)

        def row(c, x, si):
            return jax.lax.dynamic_update_slice(
                c, x.astype(c.dtype), (si,) + zeros)

        return jax.vmap(row)(cache, new, s)
    if s.ndim == 1:
        s = s[:, None]
    b = jnp.arange(cache.shape[0], dtype=jnp.int32)[:, None]
    return cache.at[b, s].set(new.astype(cache.dtype))


def ring_cache_store(k: jax.Array, total: int, cache_len: int) -> jax.Array:
    """Place the last min(total, cache_len) positions of ``k`` (B, S, ...)
    into a cache_len-slot ring buffer so that slot ``p % cache_len`` holds
    position ``p`` — the invariant decode's ring write (``ring_cache_update``
    at ``pos % T``) relies on. Unused slots are zero-filled."""
    S, T = total, cache_len
    keep = min(S, T)
    kk = k[:, S - keep:]
    if T > keep:
        kk = jnp.pad(kk, ((0, 0), (0, T - keep)) + ((0, 0),) * (k.ndim - 2))
    shift = (S - keep) % T
    return jnp.roll(kk, shift, axis=1) if shift else kk


def ring_position_ids(batch: int, total: int, cache_len: int) -> jax.Array:
    """(batch, cache_len) absolute positions matching ``ring_cache_store``'s
    layout after a ``total``-token prefill; empty slots hold -1 (masked)."""
    keep = min(total, cache_len)
    ids = jnp.concatenate([
        jnp.arange(total - keep, total, dtype=jnp.int32),
        jnp.full((cache_len - keep,), -1, jnp.int32)])
    shift = (total - keep) % cache_len
    if shift:
        ids = jnp.roll(ids, shift)
    return jnp.tile(ids[None], (batch, 1))


# ---------------------------------------------------------------------------
# Proteus-quantized KV cache (REPRO_KV_QUANT=off|int8|int4|auto)
#
# Decode is memory-bandwidth-bound: every generated token streams the whole
# ring KV cache through the decode kernel, so kv bytes/token — not FLOPs —
# sets tokens/s. The Proteus runtime's narrow-value machinery applied to that
# stream: K/V rows are stored as block-scaled int8 (or nibble-packed int4)
# codes with one fp32 scale per (slot, kv head) row (block = head_dim), and
# the Pallas decode kernel dequantizes per tile in VMEM — HBM reads only the
# narrow codes. ``auto`` keeps int8 storage but picks the quantization grid
# per tensor data-aware via ``required_bits_float`` (uniform-magnitude
# tensors take the int4 grid; spiky ones the int8 grid) — the DBPE analogue,
# transparent to every call site.
# ---------------------------------------------------------------------------
# auto-mode error target (per-element quant error vs block mean |x|): the
# narrowest crest (uniform magnitudes, crest = 1) needs ceil(log2(1/(2r)+1))+1
# bits, so r = 0.1 is the loosest target at which the int4 grid (4 bits,
# crest <= 1.4) is ever feasible while gaussian-crest (~3.5) rows still
# escalate to the int8 grid.
KV_QUANT_RTOL = 0.1

# Documented worst |output| deviation vs the bf16 cache for unit-normal
# q/k/v — the single source of truth for the pytest gate, the bench/CI gate
# (benchmarks/bench_kernels.py), and the README error-budget table. ``auto``
# stores int8-width codes, so it inherits the int8 budget.
KV_ERROR_BUDGET = {"int8": 0.05, "int4": 0.25, "auto": 0.05}


@jax.tree_util.register_pytree_node_class
@dataclass
class QKVCache:
    """Quantized KV-cache leaf: ``codes`` int8 ``(..., T, H, Dc)`` with
    ``Dc = D`` (int8/auto) or ``D // 2`` (nibble-packed int4), and ``scale``
    fp32 ``(..., T, H)``. Static shapes and a flat two-leaf pytree, so the
    fused ``lax.scan`` decode loop, donation, and the engine's slot swaps
    work unchanged."""

    codes: jax.Array
    scale: jax.Array

    def tree_flatten(self):
        return (self.codes, self.scale), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def kv_len(self) -> int:
        return self.codes.shape[-3]

    @property
    def num_heads(self) -> int:
        return self.codes.shape[-2]


def _kv_qmax(x: jax.Array, mode: str):
    if mode == "int8":
        return 127.0
    if mode == "int4":
        return 7.0
    # auto: data-aware narrow-value detection over head_dim rows (the quant
    # blocks); <= 4 consequential bits -> the int4 grid is safe.
    bits = required_bits_float(x, block=x.shape[-1], rtol=KV_QUANT_RTOL)
    return jnp.where(bits <= 4, 7.0, 127.0)


def kv_quantize(x: jax.Array, mode: str) -> QKVCache:
    """Symmetric per-row quantization of ``x`` (..., T, H, D)."""
    xf = x.astype(jnp.float32)
    qmax = _kv_qmax(xf, mode)
    maxabs = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(maxabs == 0, 1.0, maxabs / qmax)
    codes = jnp.clip(jnp.round(xf / scale[..., None]),
                     -qmax - 1, qmax).astype(jnp.int8)
    if mode == "int4":
        codes = pack_int4(codes)
    return QKVCache(codes, scale.astype(jnp.float32))


def kv_dequantize(qkv: QKVCache, head_dim: int, dtype) -> jax.Array:
    """jnp fallback dequant (non-TPU / forced-jnp backends): the Pallas
    decode kernel dequantizes in VMEM instead and never calls this."""
    codes = qkv.codes
    if codes.shape[-1] != head_dim:
        codes = unpack_int4(codes)
    return (codes.astype(jnp.float32)
            * qkv.scale[..., None]).astype(dtype)


def maybe_kv_quantize(x: jax.Array, mode: Optional[str] = None):
    """Quantize a cache-layout tensor unless the mode is ``off``."""
    mode = kv_quant_mode() if mode is None else mode
    return x if mode == "off" else kv_quantize(x, mode)


# ---------------------------------------------------------------------------
# Paged KV cache (REPRO_KV_PAGES=<tokens-per-page>, block-table layout)
#
# The contiguous per-slot ring cache statically over-allocates HBM: every
# slot owns cache_len rows whether its prompt filled them or not — the
# "statically over-allocated resources" problem the paper's MIMDRAM line
# solves in DRAM by allocating per-kernel. The paged layout splits the cache
# into fixed-size pages in ONE pool array plus a per-slot int32 page table
# (static shapes, so the fused lax.scan decode, donation and the engine's
# slot swaps are unchanged); the serving engine pairs it with a free-list
# allocator and hash-consed prefix sharing so only pages actually holding
# tokens occupy distinct HBM. Physical page 0 is a reserved trash page:
# retired/unused table rows point at it, so stale slots keep decoding
# harmlessly and shared pages are never overwritten by a redirected write.
# ---------------------------------------------------------------------------
TRASH_PAGE = 0


@jax.tree_util.register_pytree_node_class
@dataclass
class PagedKVCache:
    """Paged KV-cache leaf: ``pages`` is the pool — a plain array
    ``(..., P, ps, H, D)`` or a :class:`QKVCache` of pooled codes+scales —
    and ``table`` int32 ``(..., B, NP)`` maps each slot's logical page to a
    physical pool index (0 = trash page). Leading ``...`` dims (layers /
    groups) are shared between pool and table so ``lax.scan`` over layers
    unstacks both together."""

    pages: Any
    table: jax.Array

    def tree_flatten(self):
        return (self.pages, self.table), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def _pool(self) -> jax.Array:
        return (self.pages.codes if isinstance(self.pages, QKVCache)
                else self.pages)

    @property
    def page_size(self) -> int:
        return self._pool.shape[-3]

    @property
    def num_pages(self) -> int:
        """Physical pool capacity P (including the trash page)."""
        return self._pool.shape[-4]

    @property
    def kv_len(self) -> int:
        """Logical per-slot capacity T = NP * page_size."""
        return self.table.shape[-1] * self.page_size

    @property
    def num_heads(self) -> int:
        return self._pool.shape[-2]


def aligned_cache_len(n: int, page_size: Optional[int] = None) -> int:
    """Round a cache length up to the page multiple in paged mode (identity
    otherwise) so per-slot capacity is a whole number of pages and the ring
    invariant ``slot = pos % T`` maps cleanly onto logical pages."""
    ps = kv_page_size() if page_size is None else page_size
    return n if ps <= 0 else -(-n // ps) * ps


def _identity_table(batch: int, n_pages: int, lead: Tuple[int, ...] = ()):
    """Default table: slot b's logical page i -> physical page 1 + b*NP + i
    (page 0 stays the trash page) — standalone decode and spec dryruns work
    without an allocator."""
    t = (1 + jnp.arange(batch * n_pages, dtype=jnp.int32)).reshape(
        batch, n_pages)
    return jnp.broadcast_to(t, lead + (batch, n_pages))


def paged_from_ring(ring, page_size: Optional[int] = None,
                    mode: Optional[str] = None) -> "PagedKVCache":
    """Re-layout a ring cache ``(B, T, H, D)`` as a :class:`PagedKVCache`:
    slot b's pages land at pool rows 1 + b*NP .. with the identity table."""
    ps = kv_page_size() if page_size is None else page_size
    mode = kv_quant_mode() if mode is None else mode
    B, T = ring.shape[:2]
    npg = T // ps
    q = ring if mode == "off" else kv_quantize(ring, mode)

    def to_pool(x):                     # (B, T, ...) -> (1 + B*NP, ps, ...)
        px = x.reshape((B * npg, ps) + x.shape[2:])
        return jnp.concatenate([jnp.zeros_like(px[:1]), px])

    pages = (QKVCache(to_pool(q.codes), to_pool(q.scale))
             if isinstance(q, QKVCache) else to_pool(q))
    return PagedKVCache(pages, _identity_table(B, npg))


def paged_gather(cache: "PagedKVCache"):
    """Dense ``(B, T, H, D)`` view (plain or :class:`QKVCache`) of a paged
    cache: one pool gather per call — the jnp fallback path; the Pallas
    paged kernel streams pages via the table instead and never calls this."""
    table = cache.table                              # (B, NP)
    B = table.shape[0]

    def g(pool):
        x = pool[table]                              # (B, NP, ps, ...)
        return x.reshape((B, -1) + pool.shape[2:])

    if isinstance(cache.pages, QKVCache):
        return QKVCache(g(cache.pages.codes), g(cache.pages.scale))
    return g(cache.pages)


def kv_cache_init(shape: Tuple[int, ...], dtype,
                  mode: Optional[str] = None,
                  page_size: Optional[int] = None):
    """Zeros KV-cache leaf for logical shape ``(..., B, T, H, D)``: a plain
    array when quantization is off, else a :class:`QKVCache`; either is
    wrapped in a :class:`PagedKVCache` (identity table, +1 trash page) when
    paged mode is on."""
    mode = kv_quant_mode() if mode is None else mode
    ps = kv_page_size() if page_size is None else page_size
    if ps > 0:
        lead, (B, T, H, D) = shape[:-4], shape[-4:]
        assert T % ps == 0, (
            f"paged cache_len {T} not a multiple of page size {ps}; "
            "size caches via aligned_cache_len")
        npg = T // ps
        pages = _kv_zeros(lead + (B * npg + 1, ps, H, D), dtype, mode)
        return PagedKVCache(pages, _identity_table(B, npg, lead))
    return _kv_zeros(shape, dtype, mode)


def _kv_zeros(shape: Tuple[int, ...], dtype, mode: str):
    if mode == "off":
        return jnp.zeros(shape, dtype)
    dc = shape[-1] // 2 if mode == "int4" else shape[-1]
    return QKVCache(jnp.zeros(shape[:-1] + (dc,), jnp.int8),
                    jnp.zeros(shape[:-1], jnp.float32))


def kv_cache_axes(axes: Tuple, mode: Optional[str] = None,
                  page_size: Optional[int] = None):
    """Logical-axis tree matching :func:`kv_cache_init`'s structure."""
    mode = kv_quant_mode() if mode is None else mode
    ps = kv_page_size() if page_size is None else page_size
    if ps > 0:
        lead = tuple(axes[:-4])
        # pool has no batch axis (pages are shared across slots): replicate
        # it; the table keeps the slot axis.
        pool = lead + ("cache_pages", "cache_page_seq") + tuple(axes[-2:])
        pages = pool if mode == "off" else QKVCache(pool, pool[:-1])
        return PagedKVCache(pages, lead + (axes[-4], "cache_pages"))
    if mode == "off":
        return axes
    return QKVCache(tuple(axes), tuple(axes[:-1]))


def kv_cache_store(k: jax.Array, total: int, cache_len: int,
                   mode: Optional[str] = None,
                   page_size: Optional[int] = None):
    """Prefill store: ring-place, (maybe) quantize, (maybe) page."""
    mode = kv_quant_mode() if mode is None else mode
    ps = kv_page_size() if page_size is None else page_size
    ring = ring_cache_store(k, total, cache_len)
    if ps > 0:
        return paged_from_ring(ring, ps, mode)
    return ring if mode == "off" else kv_quantize(ring, mode)


def kv_cache_update(cache, new: jax.Array, slot: jax.Array,
                    mode: Optional[str] = None):
    """Ring/paged cache write: quantizes ``new`` (B, S, H, D) row-wise before
    the write when the cache is quantized; paged caches scatter each row into
    ``pool[table[b, slot // ps], slot % ps]`` (rows whose table entry is the
    trash page collide there harmlessly). ``slot`` is (B,) for the per-token
    decode path (S == 1) or (B, S) for a speculative verify block."""
    if isinstance(cache, PagedKVCache):
        ps = cache.page_size
        s = slot.astype(jnp.int32)
        b = jnp.arange(cache.table.shape[-2], dtype=jnp.int32)
        if new.shape[1] == 1 and s.ndim == 1:
            phys = cache.table[b, s // ps]           # (B,)
            off = s % ps

            def wr(pool, x):                         # x: (B, 1, ...)
                return pool.at[phys, off].set(x[:, 0].astype(pool.dtype))
        else:
            if s.ndim == 1:
                s = s[:, None]
            phys = cache.table[b[:, None], s // ps]  # (B, S)
            off = s % ps

            def wr(pool, x):                         # x: (B, S, ...)
                return pool.at[phys, off].set(x.astype(pool.dtype))

        if isinstance(cache.pages, QKVCache):
            mode = kv_quant_mode() if mode is None else mode
            q = kv_quantize(new, mode)
            return PagedKVCache(QKVCache(wr(cache.pages.codes, q.codes),
                                         wr(cache.pages.scale, q.scale)),
                                cache.table)
        return PagedKVCache(wr(cache.pages, new), cache.table)
    if not isinstance(cache, QKVCache):
        return ring_cache_update(cache, new, slot)
    mode = kv_quant_mode() if mode is None else mode
    q = kv_quantize(new, mode)
    return QKVCache(ring_cache_update(cache.codes, q.codes, slot),
                    ring_cache_update(cache.scale, q.scale, slot))


def kv_cache_len(cache) -> int:
    """Logical cache capacity T of a (stacked / quantized / paged) leaf."""
    if isinstance(cache, PagedKVCache):
        return cache.kv_len
    return (cache.codes if isinstance(cache, QKVCache) else cache).shape[-3]


def kv_cast(cache, dtype):
    """``cache.astype(dtype)`` for plain arrays; identity for QKVCache (the
    attention dispatch consumes codes+scales directly); recurses into the
    pool for paged caches."""
    if isinstance(cache, PagedKVCache):
        return PagedKVCache(kv_cast(cache.pages, dtype), cache.table)
    return cache if isinstance(cache, QKVCache) else cache.astype(dtype)


def stack_trees(xs):
    """Stack a list of identically-structured pytrees leaf-wise (the
    unrolled-layers analogue of ``lax.scan`` ys stacking)."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *xs)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (GQA, causal / sliding-window / cross)
# ---------------------------------------------------------------------------
def _attn_tile(qc, kc, vc, mask, m, l, acc, scale, cap):
    """One (q-tile, kv-tile) online-softmax update.

    qc: (B, Cq, K, G, D)   kc/vc: (B, Ck, K, D)
    mask: (Cq, Ck) bool, or (B, Cq, Ck) for per-sequence positions
    m, l: (B, K, G, Cq)    acc: (B, Cq, K, G, D)
    """
    mb = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
    s = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc,
                   preferred_element_type=jnp.float32) * scale
    if cap > 0:
        s = softcap(s, cap)
    s = jnp.where(mb, s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    # guard fully-masked tiles: exp(NEG_INF - NEG_INF) would be 1
    safe_m = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - safe_m[..., None])
    p = jnp.where(mb, p, 0.0)
    alpha = jnp.where(m <= NEG_INF / 2, 0.0, jnp.exp(m - safe_m))
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vc.dtype), vc,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


def _decode_positions(q_offset, kv_positions, kv_valid_len, B, S, T):
    """Per-sequence (B, S) q positions and (B, T) kv positions for the
    decode kernels; kv_valid_len folds into the -1 (masked) sentinel."""
    q_off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
    q_pos = q_off[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    if kv_positions is None:
        kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    else:
        kv_pos = jnp.broadcast_to(kv_positions.astype(jnp.int32), (B, T))
    if kv_valid_len is not None:
        valid = jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32), (B,))
        kv_pos = jnp.where(kv_pos < valid[:, None], kv_pos, -1)
    return q_pos, kv_pos


def chunked_attention(
    q: jax.Array,                 # (B, S, Hq, D)
    k: Any,                       # (B, T, Hkv, D) array, or QKVCache
    v: Any,                       # (B, T, Hkv, D) array, or QKVCache
    *,
    causal: bool = True,
    window: int = 0,              # >0: sliding-window attention
    q_offset: Any = 0,            # position of q[0]: int, traced scalar, or (B,)
    kv_positions: Optional[jax.Array] = None,  # (T,) or (B, T) abs positions
    kv_valid_len: Any = None,     # mask kv positions >= this: scalar or (B,)
    chunk_q: int = 512,
    chunk_kv: int = 1024,
    attn_softcap: float = 0.0,
    block_skip: bool = False,     # beyond-paper: skip fully-masked kv tiles
    impl: Optional[str] = None,   # 'pallas' | 'jnp' | None = REPRO_ATTN_IMPL
) -> jax.Array:
    """Tiled attention with online softmax; O(Cq*Ck) live scores memory.

    Backend dispatch: see the module docstring. Non-block-multiple S/T are
    padded to the chunk multiple (padded kv carries -1 positions / a static
    valid length, so it is masked) and the output sliced back — odd prompt
    lengths are legal on every path.
    """
    paged = isinstance(k, PagedKVCache)
    B, S, Hq, D = q.shape
    if paged:
        assert isinstance(v, PagedKVCache), "k paged but v is not"
        quant = isinstance(k.pages, QKVCache)
        T, Hkv = k.kv_len, k.num_heads
    else:
        quant = isinstance(k, QKVCache)
        if quant:
            assert isinstance(v, QKVCache), "k quantized but v is not"
            T, Hkv = k.kv_len, k.num_heads
        else:
            _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    cq = min(chunk_q, S)
    ck = min(chunk_kv, T)
    backend = attn_impl() if impl is None else impl

    # paged KV (block tables): the Pallas paged kernel streams pages straight
    # from the pool via scalar-prefetch page-table lookups; every other path
    # first gathers the slot's pages into the dense (B, T, H, D) layout.
    if paged:
        if (backend == "pallas" and S * G <= PALLAS_DECODE_MAX_Q_ROWS
                and paged_decode_supported()):
            q_pos, kv_pos = _decode_positions(q_offset, kv_positions,
                                              kv_valid_len, B, S, T)
            if quant:
                return flash_decode_paged_quant(
                    q, k.pages.codes, k.pages.scale, v.pages.codes,
                    v.pages.scale, k.table, q_pos, kv_pos, causal=causal,
                    window=window, softcap=attn_softcap)
            return flash_decode_paged(q, k.pages, v.pages, k.table, q_pos,
                                      kv_pos, causal=causal, window=window,
                                      softcap=attn_softcap)
        k = paged_gather(k)
        v = paged_gather(v)

    # training/prefill path: flash custom-VJP (O(S) activation memory)
    if (not quant and kv_positions is None and kv_valid_len is None and S > 1
            and isinstance(q_offset, int) and q_offset == 0):
        Sp = -(-S // cq) * cq
        Tp = -(-T // ck) * ck
        qp = pad_axis(q, 1, Sp)
        kp = pad_axis(k, 1, Tp)
        vp = pad_axis(v, 1, Tp)
        kv_len = 0 if Tp == T else T
        qg = qp.reshape(B, Sp, Hkv, G, D)
        if backend == "pallas":
            out = flash_attention_pallas(qg, kp, vp, causal, window,
                                         attn_softcap, cq, ck, kv_len, None)
        else:
            out = flash_attention_jnp(qg, kp, vp, causal, window, attn_softcap,
                                      cq, ck, block_skip, kv_len)
        out = out.reshape(B, Sp, Hq, D)
        return out[:, :S] if Sp != S else out

    # decode path (small q against a possibly-ring KV cache): the Pallas
    # decode kernel takes per-sequence q positions + per-slot kv positions
    # (-1 = empty slot; kv_valid_len folds into the same sentinel).
    if backend == "pallas":
        if S * G <= PALLAS_DECODE_MAX_Q_ROWS:
            q_pos, kv_pos = _decode_positions(q_offset, kv_positions,
                                              kv_valid_len, B, S, T)
            if quant:
                # in-kernel dequant: HBM reads only codes + scales
                return flash_decode_quant(
                    q, k.codes, k.scale, v.codes, v.scale, q_pos, kv_pos,
                    causal=causal, window=window, softcap=attn_softcap,
                    block_k=ck)
            return flash_decode(q, k, v, q_pos, kv_pos, causal=causal,
                                window=window, softcap=attn_softcap,
                                block_k=ck)
        warnings.warn(
            f"chunked_attention: positional call with {S * G} q-block rows "
            f"exceeds PALLAS_DECODE_MAX_Q_ROWS={PALLAS_DECODE_MAX_Q_ROWS}; "
            "falling back to the jnp path", stacklevel=2)

    # generic jnp fallback (batched positions, any q length); quantized kv
    # is dequantized up front here — only the Pallas decode kernel reads the
    # narrow codes directly.
    if quant:
        k = kv_dequantize(k, D, q.dtype)
        v = kv_dequantize(v, D, q.dtype)
    S0 = S
    Sp = -(-S // cq) * cq
    Tp = -(-T // ck) * ck
    if Tp != T:
        if kv_positions is None:
            kv_positions = jnp.arange(T, dtype=jnp.int32)
        kv_positions = pad_positions(kv_positions, Tp)
        k = pad_axis(k, 1, Tp)
        v = pad_axis(v, 1, Tp)
        T = Tp
    if Sp != S:
        q = pad_axis(q, 1, Sp)
        S = Sp
    nq, nk = S // cq, T // ck

    qg = q.reshape(B, nq, cq, Hkv, G, D)
    kg = k.reshape(B, nk, ck, Hkv, D)
    vg = v.reshape(B, nk, ck, Hkv, D)
    # per-sequence positions (continuous batching: every slot has its own pos)
    batched = (getattr(q_offset, "ndim", 0) >= 1
               or (kv_positions is not None and kv_positions.ndim == 2)
               or getattr(kv_valid_len, "ndim", 0) >= 1)
    if batched:
        q_off = jnp.broadcast_to(jnp.asarray(q_offset, jnp.int32), (B,))
        if kv_positions is None:
            kv_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        else:
            kv_pos = jnp.broadcast_to(kv_positions.astype(jnp.int32), (B, T))
        kv_pos = kv_pos.reshape(B, nk, ck)
        kv_valid = (None if kv_valid_len is None
                    else jnp.broadcast_to(jnp.asarray(kv_valid_len, jnp.int32),
                                          (B,)))
    elif kv_positions is None:
        kv_pos = jnp.arange(T, dtype=jnp.int32).reshape(nk, ck)
    else:
        kv_pos = kv_positions.astype(jnp.int32).reshape(nk, ck)

    def q_chunk(i):
        qc = qg[:, i].astype(jnp.float32)  # fp32 q tile for stable softmax
        if batched:
            q_pos = (q_off[:, None] + i * cq
                     + jnp.arange(cq, dtype=jnp.int32)[None, :])   # (B, cq)
        else:
            q_pos = q_offset + i * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry, j):
            m, l, acc = carry
            kc = kg[:, j]
            vc = vg[:, j]
            if batched:
                kp = kv_pos[:, j]                                  # (B, ck)
                mask = kp[:, None, :] >= 0                         # (B, cq, ck)
                if causal:
                    mask &= kp[:, None, :] <= q_pos[:, :, None]
                if window > 0:
                    mask &= kp[:, None, :] > q_pos[:, :, None] - window
                if kv_valid is not None:
                    mask &= kp[:, None, :] < kv_valid[:, None, None]
            else:
                kp = kv_pos[j]
                mask = jnp.ones((cq, ck), dtype=bool)
                mask &= kp[None, :] >= 0
                if causal:
                    mask &= kp[None, :] <= q_pos[:, None]
                if window > 0:
                    mask &= kp[None, :] > q_pos[:, None] - window
                if kv_valid_len is not None:
                    mask &= kp[None, :] < kv_valid_len
            m, l, acc = _attn_tile(qc.astype(k.dtype), kc, vc, mask, m, l, acc,
                                   scale, attn_softcap)
            return (m, l, acc), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, Hkv, G, D), jnp.float32)

        if block_skip and causal and kv_positions is None and kv_valid_len is None:
            # beyond-paper optimization: statically bound the kv range per
            # q-tile; tiles wholly above the causal diagonal are never built.
            hi = 0
            if isinstance(q_offset, int):
                hi = (q_offset + (i + 1) * cq + ck - 1) // ck
                lo = 0
                if window > 0:
                    lo = max(0, (q_offset + i * cq - window) // ck)
                idx = jnp.arange(lo, max(hi, lo + 1), dtype=jnp.int32)
                (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), idx)
            else:
                (m, l, acc), _ = jax.lax.scan(
                    kv_step, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32))
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), jnp.arange(nk, dtype=jnp.int32))
        l = jnp.where(l == 0.0, 1.0, l)
        out = acc / l.transpose(0, 3, 1, 2)[..., None]
        return out.astype(q.dtype)  # (B, cq, Hkv, G, D)

    if nq == 1:
        out = q_chunk(0).reshape(B, S, Hq, D)
    else:
        outs = jax.lax.map(q_chunk, jnp.arange(nq, dtype=jnp.int32))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hq, D)  # (nq,B,cq,...)
    return out[:, :S0] if S != S0 else out


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (recompute-from-lse backward).
#
# The default autodiff of the chunked forward saves the fp32 (m, l, acc)
# carries of every kv step — O(S*T/ck) live fp32 — which DAMOV flagged as the
# dominant train-time memory term. The flash backward stores only (out, lse)
# and rebuilds p per tile: activation memory drops to O(S) per layer.
# ---------------------------------------------------------------------------
def _kv_range(i, cq, ck, T, causal, window, block_skip):
    """Static kv-chunk range [lo, hi) that q-chunk i can attend to."""
    nk = T // ck
    if not block_skip:
        return 0, nk
    hi = min(nk, (i * cq + cq + ck - 1) // ck) if causal else nk
    lo = max(0, (i * cq - window) // ck) if window > 0 else 0
    return lo, max(hi, lo + 1)


def _flash_fwd_impl(q, k, v, causal, window, attn_softcap, cq, ck,
                    block_skip=False, kv_len=0):
    """Returns (out, lse). q:(B,S,Hkv,G,D) k/v:(B,T,Hkv,D).
    kv_len > 0 masks kv positions >= kv_len (pad-to-block-multiple support).

    block_skip=True (beyond-paper): q-chunks are Python-unrolled so each
    scans only its statically-reachable kv chunks — causal attention does
    ~(nq+1)/2nq of the full-pair work in both FLOPs and tile traffic.
    """
    B, S, Hkv, G, D = q.shape
    T = k.shape[1]
    nq, nk = S // cq, T // ck
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, nq, cq, Hkv, G, D)
    kg = k.reshape(B, nk, ck, Hkv, D)
    vg = v.reshape(B, nk, ck, Hkv, D)

    def q_chunk(i, lo=0, hi=nk):
        qc = qg[:, i]
        q_pos = i * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry, j):
            m, l, acc = carry
            mask = _flash_mask(q_pos, j, ck, causal, window, kv_len)
            m, l, acc = _attn_tile(qc, kg[:, j], vg[:, j], mask, m, l, acc,
                                   scale, attn_softcap)
            return (m, l, acc), None

        m0 = jnp.full((B, Hkv, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, cq), jnp.float32)
        a0 = jnp.zeros((B, cq, Hkv, G, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(lo, hi, dtype=jnp.int32))
        lsafe = jnp.where(l == 0.0, 1.0, l)
        out = (acc / lsafe.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
        lse = jnp.where(m <= NEG_INF / 2, NEG_INF, m + jnp.log(lsafe))
        return out, lse                          # (B,cq,K,G,D), (B,K,G,cq)

    if nq == 1:
        out, lse = q_chunk(0, *_kv_range(0, cq, ck, T, causal, window,
                                         block_skip))
        return out.reshape(B, S, Hkv, G, D), lse[..., None, :]
    if block_skip:
        outs, lses = [], []
        for i in range(nq):
            lo, hi = _kv_range(i, cq, ck, T, causal, window, True)
            o, l = q_chunk(i, lo, hi)
            outs.append(o)
            lses.append(l)
        out = jnp.stack(outs, 1).reshape(B, S, Hkv, G, D)
        lse = jnp.stack(lses, 3)
        return out, lse
    outs, lses = jax.lax.map(q_chunk, jnp.arange(nq, dtype=jnp.int32))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, Hkv, G, D)
    lse = jnp.moveaxis(lses, 0, 3)               # (B,K,G,nq,cq)
    return out, lse


def _flash_mask(q_pos, j, ck, causal, window, kv_len=0):
    k_pos = j * ck + jnp.arange(ck, dtype=jnp.int32)
    mask = jnp.ones((q_pos.shape[0], ck), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len > 0:
        mask &= (k_pos < kv_len)[None, :]
    return mask


def _flash_tile_scores(qc, kc, scale, cap):
    s_raw = jnp.einsum("bqkgd,bskd->bkgqs", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
    if cap > 0:
        return cap * jnp.tanh(s_raw / cap), s_raw
    return s_raw, s_raw


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention_jnp(q, k, v, causal=True, window=0, attn_softcap=0.0,
                        cq=512, ck=1024, block_skip=False, kv_len=0):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, attn_softcap, cq, ck,
                             block_skip, kv_len)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, attn_softcap, cq, ck, block_skip,
                   kv_len):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, attn_softcap, cq, ck,
                               block_skip, kv_len)
    return out, (q, k, v, out, lse)


def _flash_vjp_bwd(causal, window, attn_softcap, cq, ck, block_skip, kv_len,
                   res, do):
    q, k, v, out, lse = res
    B, S, Hkv, G, D = q.shape
    T = k.shape[1]
    nq, nk = S // cq, T // ck
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, nq, cq, Hkv, G, D)
    kg = k.reshape(B, nk, ck, Hkv, D)
    vg = v.reshape(B, nk, ck, Hkv, D)
    og = out.reshape(B, nq, cq, Hkv, G, D)
    dog = do.reshape(B, nq, cq, Hkv, G, D)
    # delta = rowsum(do * o): (B,nq,cq,K,G) -> align to scores (B,K,G,cq)
    delta = (dog.astype(jnp.float32) * og.astype(jnp.float32)).sum(-1)

    def q_chunk(i, carry, lo=0, hi=nk):
        dk_acc, dv_acc = carry
        qc = qg[:, i]
        doc = dog[:, i].astype(jnp.float32)
        lse_i = lse[:, :, :, i]                                # (B,K,G,cq)
        dlt_i = delta[:, i].transpose(0, 2, 3, 1)              # (B,K,G,cq)
        q_pos = i * cq + jnp.arange(cq, dtype=jnp.int32)

        def kv_step(carry2, j):
            dq_c, dk_a, dv_a = carry2
            kc, vc = kg[:, j], vg[:, j]
            mask = _flash_mask(q_pos, j, ck, causal, window, kv_len)
            s, s_raw = _flash_tile_scores(qc, kc, scale, attn_softcap)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_i[..., None])                  # (B,K,G,q,s)
            p = jnp.where(mask[None, None, None], p, 0.0)
            dv_t = jnp.einsum("bkgqs,bqkgd->bskd", p, doc)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doc,
                            vc.astype(jnp.float32))
            ds = p * (dp - dlt_i[..., None])
            if attn_softcap > 0:
                t = jnp.tanh(s_raw / attn_softcap)
                ds = ds * (1.0 - t * t)
            dq_t = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                              kc.astype(jnp.float32)) * scale
            dk_t = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                              qc.astype(jnp.float32)) * scale
            dk_a = jax.lax.dynamic_update_slice(
                dk_a, (jax.lax.dynamic_slice(
                    dk_a, (0, j * ck, 0, 0), (B, ck, Hkv, D)) + dk_t),
                (0, j * ck, 0, 0))
            dv_a = jax.lax.dynamic_update_slice(
                dv_a, (jax.lax.dynamic_slice(
                    dv_a, (0, j * ck, 0, 0), (B, ck, Hkv, D)) + dv_t),
                (0, j * ck, 0, 0))
            return (dq_c + dq_t, dk_a, dv_a), None

        dq0 = jnp.zeros((B, cq, Hkv, G, D), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc),
            jnp.arange(lo, hi, dtype=jnp.int32))
        return dq_c, (dk_acc, dv_acc)

    dkv0 = (jnp.zeros((B, T, Hkv, D), jnp.float32),
            jnp.zeros((B, T, Hkv, D), jnp.float32))

    if block_skip:
        carry = dkv0
        dq_chunks = []
        for i in range(nq):
            lo, hi = _kv_range(i, cq, ck, T, causal, window, True)
            dq_c, carry = q_chunk(i, carry, lo, hi)
            dq_chunks.append(dq_c)
        dk, dv = carry
        dq = jnp.stack(dq_chunks, 1).reshape(B, S, Hkv, G, D)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))

    def scan_q(carry, i):
        dq_c, carry = q_chunk(i, carry)
        return carry, dq_c

    (dk, dv), dqs = jax.lax.scan(scan_q, dkv0,
                                 jnp.arange(nq, dtype=jnp.int32))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, S, Hkv, G, D)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention_jnp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


# ---------------------------------------------------------------------------
# Pallas-backed flash attention (train/prefill): Pallas forward kernel,
# recompute-based jnp backward from (out, lse) — same residual contract as
# flash_attention_jnp, so training runs on the TPU kernel with O(S)
# activation memory and no saved score tiles.
# ---------------------------------------------------------------------------
def _flash_pallas_fwd_impl(q, k, v, causal, window, attn_softcap, cq, ck,
                           kv_len, interpret):
    """q:(B,S,Hkv,G,D) k/v:(B,T,Hkv,D); S % cq == 0, T % ck == 0 (caller
    pads); kv_len > 0 masks kv positions >= kv_len. Returns (out, lse) with
    lse (B,K,G,nq,cq) — the flash_attention_jnp residual layout."""
    B, S, Hkv, G, D = q.shape
    T = k.shape[1]
    kv_pos = None
    if kv_len:
        ar = jnp.arange(T, dtype=jnp.int32)
        kv_pos = jnp.broadcast_to(jnp.where(ar < kv_len, ar, -1), (B, T))
    out, lse = flash_attention_gqa_fwd(
        q.reshape(B, S, Hkv * G, D), k, v, causal=causal, window=window,
        softcap=attn_softcap, kv_positions=kv_pos, block_q=cq, block_k=ck,
        interpret=interpret)
    return (out.reshape(B, S, Hkv, G, D),
            lse.reshape(B, Hkv, G, S // cq, cq))


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention_pallas(q, k, v, causal=True, window=0, attn_softcap=0.0,
                           cq=512, ck=1024, kv_len=0, interpret=None):
    out, _ = _flash_pallas_fwd_impl(q, k, v, causal, window, attn_softcap,
                                    cq, ck, kv_len, interpret)
    return out


def _flash_pallas_vjp_fwd(q, k, v, causal, window, attn_softcap, cq, ck,
                          kv_len, interpret):
    out, lse = _flash_pallas_fwd_impl(q, k, v, causal, window, attn_softcap,
                                      cq, ck, kv_len, interpret)
    return out, (q, k, v, out, lse)


def _flash_pallas_vjp_bwd(causal, window, attn_softcap, cq, ck, kv_len,
                          interpret, res, do):
    return _flash_vjp_bwd(causal, window, attn_softcap, cq, ck, False, kv_len,
                          res, do)


flash_attention_pallas.defvjp(_flash_pallas_vjp_fwd, _flash_pallas_vjp_bwd)


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                  kv_valid_len=None, attn_softcap=0.0):
    """Naive quadratic oracle (tests only)."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    qf = q.reshape(B, S, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if attn_softcap > 0:
        s = softcap(s, attn_softcap)
    qp = q_offset + jnp.arange(S)
    kp = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    if kv_valid_len is not None:
        mask &= kp[None, :] < kv_valid_len
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, S, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Cross-entropy
# ---------------------------------------------------------------------------
def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token cross-entropy. logits (B,S,V) fp-any; labels (B,S)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1)
    return nll.mean()
