"""Version-adaptive JAX shims — the only sanctioned import path for
version-sensitive JAX symbols (see jax_compat.py for the support matrix)."""
from repro.compat.jax_compat import (  # noqa: F401
    HAS_DIFFERENTIABLE_BARRIER,
    HAS_NATIVE_AXIS_TYPE,
    HAS_NATIVE_MAKE_MESH,
    HAS_NATIVE_SHARD_MAP,
    HAS_PARTIAL_MANUAL_SHARD_MAP,
    JAX_VERSION,
    AxisType,
    abstract_mesh,
    axis_size,
    context_mesh,
    current_axis_types,
    describe_support,
    import_pallas,
    import_pallas_tpu,
    in_manual_context,
    is_manual_axis,
    make_mesh,
    manual_axis_names,
    optimization_barrier,
    pallas_call,
    shard_map,
    tree_all,
    tree_flatten,
    tree_leaves,
    tree_map,
    tree_reduce,
    tree_structure,
    tree_unflatten,
)

from repro.compat.jax_compat import __all__  # noqa: F401
