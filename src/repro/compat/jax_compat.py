"""Version-adaptive JAX compatibility layer.

Everything in this repo that touches a JAX API whose spelling changed between
JAX generations goes through this module — the same way the thesis' system
support hides PIM hardware-generation differences from programmers, this shim
hides JAX-generation differences from every kernel, model, and launch path.
New code MUST import version-sensitive symbols from ``repro.compat`` only
(enforced by tests/test_compat.py and CI).

Support matrix (selected at import time):

  symbol / behaviour        JAX 0.4.x (>= 0.4.35)            JAX >= 0.5
  ------------------------  -------------------------------  -----------------------------
  shard_map                 jax.experimental.shard_map       jax.shard_map
    partial-manual axes     auto= (complement of manual      axis_names= (the manual set)
                            set; jit-only — the 0.4.x
                            eager impl raises
                            NotImplementedError)
    replication check flag  check_rep=                       check_vma=
  AxisType                  local enum stub (Auto/           jax.sharding.AxisType
                            Explicit/Manual)
  make_mesh                 jax.make_mesh (axis_types        jax.make_mesh
                            kwarg dropped); pre-0.4.35
                            fallback via mesh_utils
  manual-axis detection     thread-local recorded by this    jax.sharding.get_abstract_mesh()
    (is_manual_axis, ...)   module's shard_map wrapper at      .axis_types, with the same
                            trace time (0.4.x tracing only     thread-local as tie-breaker
                            exposes manual axes through        for exact nested-context
                            SPMDAxisContext at lowering,       info
                            too late for trace-time policy)
  pallas entry points       jax.experimental.pallas(+.tpu)   same (re-exported lazily)
  tree utilities            jax.tree.* with jax.tree_util    jax.tree.*
                            fallback

Known 0.4.x behaviour change: a partial-manual ``shard_map`` (``axis_names``
a strict subset of the mesh axes) is *promoted to fully-manual* there — the
0.4.x jaxlib SPMD partitioner hard-crashes on manual-subgroup modules and
the eager ``auto=`` path is unimplemented upstream. See the
``HAS_PARTIAL_MANUAL_SHARD_MAP`` note below for the exact conditions and
cost.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect
import re as _re
import threading
from typing import Any, Callable, Dict, FrozenSet, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh

__all__ = [
    "JAX_VERSION",
    "HAS_NATIVE_SHARD_MAP",
    "HAS_NATIVE_AXIS_TYPE",
    "HAS_NATIVE_MAKE_MESH",
    "HAS_PARTIAL_MANUAL_SHARD_MAP",
    "HAS_DIFFERENTIABLE_BARRIER",
    "optimization_barrier",
    "axis_size",
    "AxisType",
    "shard_map",
    "make_mesh",
    "abstract_mesh",
    "context_mesh",
    "manual_axis_names",
    "current_axis_types",
    "is_manual_axis",
    "in_manual_context",
    "import_pallas",
    "import_pallas_tpu",
    "pallas_call",
    "pallas_prefetch_grid_spec",
    "pallas_vmem_scratch",
    "tree_map",
    "tree_leaves",
    "tree_flatten",
    "tree_unflatten",
    "tree_structure",
    "tree_reduce",
    "tree_all",
    "describe_support",
]


def _parse_version(v: str) -> Tuple[int, ...]:
    """Leading numeric release components only ('0.5.0rc1' -> (0, 5, 0))."""
    parts = []
    for p in v.split("."):
        m = _re.match(r"\d+", p)
        if m is None:
            break
        parts.append(int(m.group()))
        if m.group() != p:  # mixed part like '0rc1': stop after its number
            break
    return tuple(parts[:3])


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)

# ---------------------------------------------------------------------------
# AxisType
# ---------------------------------------------------------------------------
try:  # >= 0.5 public spelling
    from jax.sharding import AxisType  # type: ignore[attr-defined]

    HAS_NATIVE_AXIS_TYPE = True
except ImportError:
    HAS_NATIVE_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        """Stub of jax.sharding.AxisType for JAX < 0.5."""

        Auto = enum.auto()
        Explicit = enum.auto()
        Manual = enum.auto()


# ---------------------------------------------------------------------------
# shard_map: one spelling for every JAX generation
# ---------------------------------------------------------------------------
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
if HAS_NATIVE_SHARD_MAP:
    _raw_shard_map = jax.shard_map  # type: ignore[attr-defined]
else:
    from jax.experimental.shard_map import shard_map as _raw_shard_map

_RAW_PARAMS = frozenset(inspect.signature(_raw_shard_map).parameters)

# Partial-manual (a strict subset of mesh axes manual, the rest left to
# GSPMD) is only dependable from 0.5 on: the 0.4.x jaxlib SPMD partitioner
# hard-CHECK-fails (process abort) on many manual-subgroup modules
# (spmd_partitioner.cc / hlo_sharding_util.cc), and the eager interpreter
# path raises NotImplementedError. On 0.4.x this shim therefore *promotes*
# partial-manual maps to fully-manual — legal whenever no in/out spec
# mentions an auto axis and the body only issues collectives over its manual
# axes (both true throughout this repo; the spec condition is verified at
# call time). The cost is that GSPMD no longer distributes the body over the
# auto axes on 0.4.x (redundant replicated compute there); semantics and
# results are unchanged.
HAS_PARTIAL_MANUAL_SHARD_MAP = JAX_VERSION >= (0, 5)

# Thread-local stack of (abstract mesh, frozenset(manual axis names)),
# pushed while the body of a compat shard_map is being traced. This is the
# 0.4.x source of truth for manual-axis queries (the tracing axis env binds
# auto axes too, so it cannot distinguish manual from auto there).
_trace_ctx = threading.local()


def _ctx_stack():
    stack = getattr(_trace_ctx, "stack", None)
    if stack is None:
        stack = _trace_ctx.stack = []
    return stack


@contextlib.contextmanager
def _recording_manual(mesh, manual: FrozenSet[str]):
    stack = _ctx_stack()
    stack.append((abstract_mesh(mesh), manual))
    try:
        yield
    finally:
        stack.pop()


def _spec_axis_names(specs) -> FrozenSet[str]:
    """Every mesh axis name mentioned anywhere in a pytree of PartitionSpecs."""
    from jax.sharding import PartitionSpec  # noqa: PLC0415

    names: set = set()
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, PartitionSpec))
    for leaf in leaves:
        if not isinstance(leaf, PartitionSpec):
            continue
        for entry in leaf:
            if entry is None:
                continue
            if isinstance(entry, (tuple, list)):
                names.update(entry)
            else:
                names.add(entry)
    return frozenset(names)


def shard_map(
    f: Callable,
    mesh=None,
    in_specs: Any = None,
    out_specs: Any = None,
    *,
    axis_names: Optional[FrozenSet[str]] = None,
    check_vma: Optional[bool] = None,
    check_rep: Optional[bool] = None,
    auto: Optional[FrozenSet[str]] = None,
):
    """Normalized shard_map across JAX generations.

    ``axis_names`` is the >=0.5 spelling: the set of mesh axes that are
    *manual* inside ``f`` (omitted = all axes manual). ``auto`` (the 0.4.x
    spelling: the complement) is accepted for symmetry; pass at most one.
    ``check_vma`` / ``check_rep`` are the same flag under its new / old name.
    """
    if mesh is None:
        raise TypeError("shard_map: mesh is required")
    all_axes = frozenset(mesh.axis_names)
    if axis_names is not None and auto is not None:
        raise TypeError("shard_map: pass axis_names or auto, not both")
    if axis_names is not None:
        manual = frozenset(axis_names)
    elif auto is not None:
        manual = all_axes - frozenset(auto)
    else:
        manual = all_axes
    if not manual <= all_axes:
        raise ValueError(
            f"shard_map: manual axes {sorted(manual)} not a subset of mesh "
            f"axes {sorted(all_axes)}")
    if manual != all_axes and not HAS_PARTIAL_MANUAL_SHARD_MAP:
        offending = (_spec_axis_names(in_specs)
                     | _spec_axis_names(out_specs)) & (all_axes - manual)
        if offending:
            raise NotImplementedError(
                f"jax {jax.__version__} cannot partition partial-manual "
                f"shard_map whose specs mention auto axes {sorted(offending)}"
                " (the 0.4.x fully-manual promotion needs specs confined to "
                "the manual axes)")
        manual = all_axes  # promote: see HAS_PARTIAL_MANUAL_SHARD_MAP note
    check = True
    if check_vma is not None:
        check = check_vma
    elif check_rep is not None:
        check = check_rep

    @functools.wraps(f)
    def traced(*args, **kwargs):
        with _recording_manual(mesh, manual):
            return f(*args, **kwargs)

    kw: Dict[str, Any] = {"mesh": mesh, "in_specs": in_specs,
                          "out_specs": out_specs}
    if "check_vma" in _RAW_PARAMS:
        kw["check_vma"] = check
    elif "check_rep" in _RAW_PARAMS:
        kw["check_rep"] = check
    if manual != all_axes:
        if "axis_names" in _RAW_PARAMS:
            kw["axis_names"] = set(manual)
        elif "auto" in _RAW_PARAMS:
            kw["auto"] = all_axes - manual
        else:  # pragma: no cover - no partial-manual support at all
            raise NotImplementedError(
                f"installed jax {jax.__version__} shard_map supports neither "
                "axis_names= nor auto=; partial-manual maps unavailable")
    return _raw_shard_map(traced, **kw)


# ---------------------------------------------------------------------------
# Mesh construction
# ---------------------------------------------------------------------------
_native_make_mesh = getattr(jax, "make_mesh", None)
HAS_NATIVE_MAKE_MESH = _native_make_mesh is not None
_MM_PARAMS = (frozenset(inspect.signature(_native_make_mesh).parameters)
              if HAS_NATIVE_MAKE_MESH else frozenset())


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None) -> Mesh:
    """jax.make_mesh across generations.

    ``axis_types`` is honoured when the installed JAX understands it
    (>= 0.5) and silently dropped otherwise — 0.4.x meshes are untyped and
    axis-type policy is carried by this module's shard_map wrapper instead.
    """
    if HAS_NATIVE_MAKE_MESH:
        kw: Dict[str, Any] = {}
        if devices is not None:
            kw["devices"] = devices
        if axis_types is not None and "axis_types" in _MM_PARAMS:
            kw["axis_types"] = axis_types
        return _native_make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)
    from jax.experimental import mesh_utils  # noqa: PLC0415

    devs = mesh_utils.create_device_mesh(tuple(axis_shapes), devices=devices)
    return Mesh(devs, tuple(axis_names))


def abstract_mesh(mesh):
    """The AbstractMesh view of a (possibly already abstract) mesh."""
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        return getattr(mesh, "abstract_mesh", mesh)
    return mesh  # already abstract


# ---------------------------------------------------------------------------
# Trace-context queries (manual-axis detection)
# ---------------------------------------------------------------------------
def _native_context() -> Optional[Tuple[Any, FrozenSet[str]]]:
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        return None
    m = get()
    if m is None or getattr(m, "empty", True):
        return None
    types = getattr(m, "axis_types", None) or ()
    manual = frozenset(
        n for n, t in zip(m.axis_names, types)
        if t == getattr(AxisType, "Manual", None))
    return m, manual


def context_mesh():
    """Innermost shard_map's (abstract) mesh, or None outside any."""
    stack = getattr(_trace_ctx, "stack", None)
    if stack:
        return stack[-1][0]
    native = _native_context()
    return native[0] if native else None


def manual_axis_names() -> FrozenSet[str]:
    """Mesh axes that are Manual in the current tracing context."""
    stack = getattr(_trace_ctx, "stack", None)
    if stack:
        return stack[-1][1]
    native = _native_context()
    return native[1] if native else frozenset()


def current_axis_types() -> Dict[str, "AxisType"]:
    """{axis name: AxisType} for the current context mesh ({} outside)."""
    mesh = context_mesh()
    if mesh is None:
        return {}
    manual = manual_axis_names()
    return {n: (AxisType.Manual if n in manual else AxisType.Auto)
            for n in mesh.axis_names}


def is_manual_axis(name: Optional[str] = None) -> bool:
    """Is ``name`` (or, with None, *any* axis) Manual in the current context?"""
    manual = manual_axis_names()
    return bool(manual) if name is None else name in manual


def in_manual_context() -> bool:
    """True inside a shard_map body with at least one manual axis.

    Model/planner code uses this to skip ``with_sharding_constraint`` —
    under a (partial-)manual map XLA's SPMD partitioner CHECK-fails on many
    constraint/reshard patterns (spmd_partitioner_util.cc), so GSPMD must
    propagate freely there.
    """
    return is_manual_axis(None)


# ---------------------------------------------------------------------------
# Collective helpers
# ---------------------------------------------------------------------------
_native_axis_size = getattr(jax.lax, "axis_size", None)


def axis_size(axis_name) -> int:
    """jax.lax.axis_size across generations (0.4.x lacks it).

    The psum-of-1 fallback is the classic spelling: a literal reduced over a
    named axis folds to the axis extent at trace time.
    """
    if _native_axis_size is not None:
        return _native_axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# optimization_barrier (differentiable on every supported JAX)
# ---------------------------------------------------------------------------
def _probe_differentiable_barrier() -> bool:
    try:  # trace-only: no compile, no execution
        jax.make_jaxpr(jax.grad(
            lambda x: jax.lax.optimization_barrier(x)))(1.0)
        return True
    except NotImplementedError:
        return False


HAS_DIFFERENTIABLE_BARRIER = _probe_differentiable_barrier()

if HAS_DIFFERENTIABLE_BARRIER:
    optimization_barrier = jax.lax.optimization_barrier
else:
    # 0.4.x lacks the differentiation rule upstream; mirror the later-JAX
    # semantics (barrier the cotangents too) via custom_vjp.
    @jax.custom_vjp
    def optimization_barrier(x):
        return jax.lax.optimization_barrier(x)

    def _barrier_fwd(x):
        return jax.lax.optimization_barrier(x), None

    def _barrier_bwd(_, g):
        def leaf(ct):
            dt = getattr(ct, "dtype", None)
            if dt is not None and dt == jax.dtypes.float0:
                return ct  # no barrier on symbolic zero cotangents
            return jax.lax.optimization_barrier(ct)

        return (jax.tree_util.tree_map(leaf, g),)

    optimization_barrier.defvjp(_barrier_fwd, _barrier_bwd)


# ---------------------------------------------------------------------------
# Pallas entry points
# ---------------------------------------------------------------------------
def import_pallas():
    """The pallas module (jax.experimental.pallas on every supported JAX)."""
    from jax.experimental import pallas as pl  # noqa: PLC0415

    return pl


def import_pallas_tpu():
    """The TPU pallas namespace, or None when this install lacks it."""
    try:
        from jax.experimental.pallas import tpu as pltpu  # noqa: PLC0415

        return pltpu
    except ImportError:
        return None


def pallas_call(*args, **kwargs):
    """Late-bound pl.pallas_call (resolves against the installed pallas)."""
    return import_pallas().pallas_call(*args, **kwargs)


def pallas_prefetch_grid_spec():
    """The scalar-prefetch grid-spec class (``pltpu.PrefetchScalarGridSpec``),
    or None when this install lacks it.

    Scalar-prefetch arguments are available to BlockSpec index maps before
    the kernel body runs — the mechanism that lets the paged decode kernel
    resolve data-dependent page-table lookups into kv block indices. The
    class lives in the TPU namespace and its location is version-sensitive,
    so callers must obtain it here; when it is absent the paged attention
    dispatch falls back to a pool gather + the dense decode kernel.
    """
    pltpu = import_pallas_tpu()
    if pltpu is None:
        return None
    return getattr(pltpu, "PrefetchScalarGridSpec", None)


def pallas_vmem_scratch(shape: Tuple[int, ...], dtype):
    """A VMEM scratch allocation for ``pallas_call(scratch_shapes=...)``.

    Uses ``pltpu.VMEM`` when the install has TPU Pallas; otherwise falls back
    to the generic ANY-space ``pl.MemoryRef``, which the interpreter accepts —
    so kernels carrying accumulators in scratch still run (interpret mode) on
    installs without the TPU plugin instead of dereferencing a None module.
    """
    pltpu = import_pallas_tpu()
    if pltpu is not None:
        return pltpu.VMEM(tuple(shape), dtype)
    pl = import_pallas()
    return pl.MemoryRef(tuple(shape), dtype, pl.MemorySpace.ANY)


# ---------------------------------------------------------------------------
# Tree utilities (jax.tree.* newer spelling, jax.tree_util fallback)
# ---------------------------------------------------------------------------
_tree_ns = getattr(jax, "tree", None)


def _tree(fn_new: str, fn_old: str):
    fn = getattr(_tree_ns, fn_new, None) if _tree_ns is not None else None
    return fn if fn is not None else getattr(jax.tree_util, fn_old)


tree_map = _tree("map", "tree_map")
tree_leaves = _tree("leaves", "tree_leaves")
tree_flatten = _tree("flatten", "tree_flatten")
tree_unflatten = _tree("unflatten", "tree_unflatten")
tree_structure = _tree("structure", "tree_structure")
tree_reduce = _tree("reduce", "tree_reduce")
tree_all = _tree("all", "tree_all")


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------
def describe_support() -> str:
    """One-line banner of which implementation paths this install selected."""
    return (
        f"repro.compat: jax {jax.__version__} | "
        f"shard_map={'jax.shard_map' if HAS_NATIVE_SHARD_MAP else 'jax.experimental.shard_map'} | "
        f"AxisType={'native' if HAS_NATIVE_AXIS_TYPE else 'stub'} | "
        f"make_mesh={'native' if HAS_NATIVE_MAKE_MESH else 'mesh_utils'} | "
        f"partial-manual={'native' if HAS_PARTIAL_MANUAL_SHARD_MAP else 'promoted-to-full'} | "
        f"diff-barrier={'native' if HAS_DIFFERENTIABLE_BARRIER else 'custom_vjp'} | "
        f"manual-axis detection={'native+shim' if HAS_NATIVE_AXIS_TYPE else 'shim'}"
    )
