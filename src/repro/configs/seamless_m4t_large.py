"""seamless-m4t-large-v2 — enc-dec 24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.

Encoder-decoder, multimodal (audio) [arXiv:2308.11596; hf]
Modality frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (batch, src_len, d_model) as encoder input; the text decoder decodes.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,                # decoder layers
    num_encoder_layers=24,        # encoder layers
    is_encoder_decoder=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    src_len_ratio=1.0,
    source="arXiv:2308.11596; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2,
    num_encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
)
