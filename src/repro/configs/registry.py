"""Architecture registry: ``--arch <id>`` -> (CONFIG, SMOKE_CONFIG)."""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.configs.base import ModelConfig

_ARCH_MODULES: Dict[str, str] = {
    "stablelm-3b": "repro.configs.stablelm_3b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "pimref-100m": "repro.configs.pimref_100m",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "pimref-100m")
ALL_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
