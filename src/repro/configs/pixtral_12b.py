"""pixtral-12b — vlm 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.

pixtral-ViT + mistral-nemo backbone [hf:mistralai/Pixtral-12B-2409; unverified]
ViT frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(batch, num_patches, d_model) prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    num_patches=1024,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_patches=8,
)
