"""mixtral-8x7b — MoE 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    attention_kind="sliding",
    sliding_window=4096,
    microbatches_hint=8,   # MoE backward working set; see EXPERIMENTS §Dry-run
    source="arXiv:2401.04088; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    experts_per_token=2,
    sliding_window=64,
)
