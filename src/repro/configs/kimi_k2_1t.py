"""kimi-k2-1t-a32b — MoE 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840.

MoE 384 experts top-8 — trillion-param (paper-table) [arXiv:2501.kimi2; unverified]

Memory budget note (see EXPERIMENTS.md §Dry-run): ~1T parameters cannot hold
12 B/param Adam state in 512 x 16 GB HBM; config therefore selects bf16 params +
Adafactor (factored second moment), fully sharded over (pod, data, model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,
    d_ff=2048,
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    capacity_factor=1.0,
    param_dtype="bfloat16",
    optimizer="adafactor",
    source="arXiv:2501.kimi2; unverified",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    experts_per_token=2,
    param_dtype="float32",
    optimizer="adamw",
)
