"""recurrentgemma-2b — hybrid 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000.

RG-LRU + local attention, 1 attention : 2 recurrent [arXiv:2402.19427; hf]
Sub-quadratic -> long_500k runs (bounded recurrent state + windowed KV).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    attention_kind="hybrid_local",
    local_window=2048,
    conv_width=4,
    logit_softcap=30.0,
    source="arXiv:2402.19427; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=5,  # pattern (r, r, a) + 2 trailing recurrent
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=256,
    local_window=32,
)
