"""pimref-100m — the framework's own ~100M-param reference LM.

Used by the end-to-end driver (examples/train_lm.py) and the DAMOV-style
characterization case studies; plays the role of the thesis' own evaluated
workload set.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pimref-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=32768,
    source="this work",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256
)
