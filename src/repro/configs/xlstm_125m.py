"""xlstm-125m — ssm 12L d_model=768 4H (kv=4) d_ff=0 vocab=50304.

sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]
d_ff=0: projections live inside the mLSTM/sLSTM blocks (no separate FFN).
Recurrent state is O(1) in sequence length -> long_500k runs.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,                 # layers 3, 7, 11 are sLSTM; rest mLSTM
    microbatches_hint=8,           # sLSTM time-scan residuals scale with B_loc
    scan_layers=False,             # heterogeneous blocks; 12 layers unrolled
    source="arXiv:2405.04517; unverified",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, vocab_size=256,
    slstm_every=4,
)
