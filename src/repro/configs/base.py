"""Config system: model / shape / run configuration dataclasses.

Every assigned architecture gets one module in ``repro/configs`` exporting a
``CONFIG`` (full-size, exercised only via the dry-run) and a ``SMOKE_CONFIG``
(reduced, same family, runnable on CPU). ``repro.configs.registry`` maps
``--arch`` ids to modules.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (exact dims from the assignment block)."""

    name: str
    family: str                      # dense | moe | hybrid | audio | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0                # 0 -> d_model // num_heads
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1               # every k-th layer is MoE (1 = all)
    # --- attention ---
    attention_kind: str = "full"     # full | sliding | hybrid_local (rg-lru 1:2)
    sliding_window: int = 0
    rope_theta: float = 10_000.0
    # --- hybrid / ssm ---
    local_window: int = 2048         # recurrentgemma local-attn window
    conv_width: int = 4              # temporal conv width in recurrent block
    rglru_c: float = 8.0             # RG-LRU constant c
    slstm_every: int = 0             # xlstm: every k-th block is sLSTM (0 = none)
    # --- enc-dec ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    src_len_ratio: float = 1.0       # encoder frame len = seq * ratio
    # --- vlm ---
    num_patches: int = 0             # pixtral: patch-embedding prefix length
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    # --- distribution defaults (mimdram planner hints) ---
    remat: str = "block"             # none | block | full
    optimizer: str = "adamw"         # adamw | adafactor
    scan_layers: bool = True
    microbatches_hint: int = 0       # per-arch grad-accumulation override
    # --- beyond-paper perf knobs (hillclimb; default = paper-faithful off) ---
    attn_block_skip: bool = False    # skip fully-masked causal kv tiles
    tp_pad_heads: int = 0            # pad q heads to this count for TP divisibility
    attn_chunk_q: int = 512          # flash tile sizes (HBM<->VMEM blocking)
    attn_chunk_kv: int = 1024
    # notes for DESIGN/EXPERIMENTS
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch supports 500k-token decode (bounded state)."""
        return (
            self.family in ("hybrid", "ssm")
            or (self.attention_kind == "sliding" and self.sliding_window > 0)
        )

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode

    def replace(self, **kw: Any) -> "ShapeConfig":
        return dataclasses.replace(self, **kw)


# The four assigned LM shape cells.
TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256, mode="train")
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, mode="prefill")
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, mode="decode")
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1, mode="decode")
SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in SHAPES}


@dataclass(frozen=True)
class RunConfig:
    """Training / serving run options (launcher-level)."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    seed: int = 0
    # distribution
    mesh_shape: Tuple[int, ...] = (1,)
    mesh_axes: Tuple[str, ...] = ("data",)
    microbatches: int = 0            # 0 = auto; >1 grad accumulation / PP chunks
    pipeline_stages: int = 0         # >0 enables PP over the 'pod' axis
    # proteus runtime
    proteus_enabled: bool = False
    proteus_grad_bits: int = 8       # quantized all-reduce payload width
    proteus_block: int = 256         # per-block scale granularity
    # checkpointing
    checkpoint_dir: str = ""
    checkpoint_every: int = 200
    keep_checkpoints: int = 3

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def tokens_per_step(shape: ShapeConfig) -> int:
    if shape.mode == "decode":
        return shape.global_batch            # one new token per sequence
    return shape.global_batch * shape.seq_len


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (embedding + blocks + head)."""
    d, h = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    attn = d * (nq * h) + 2 * d * (nkv * h) + (nq * h) * d
    if cfg.family == "ssm":
        # xlstm block: qkv + gates + out (mLSTM) approximation, no separate FFN
        blk = 4 * d * d + 2 * d
        n_blocks = cfg.num_layers
        total_blocks = n_blocks * blk
    else:
        if cfg.num_experts > 0:
            ffn = 3 * d * cfg.d_ff * cfg.num_experts + d * cfg.num_experts
        else:
            ffn = 3 * d * cfg.d_ff
        blk = attn + ffn + 2 * d
        total_blocks = cfg.num_layers * blk
        if cfg.is_encoder_decoder:
            # encoder blocks (self-attn + ffn) + decoder cross-attn
            total_blocks += cfg.num_encoder_layers * (attn + 3 * d * cfg.d_ff + 2 * d)
            total_blocks += cfg.num_layers * attn
    emb = cfg.vocab_size * d
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * d
    return emb + head + total_blocks


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: only routed experts count)."""
    if cfg.num_experts == 0:
        return param_count(cfg)
    dense_like = cfg.replace(num_experts=0, experts_per_token=0)
    base = param_count(dense_like)
    d = cfg.d_model
    per_expert = 3 * d * cfg.d_ff
    # subtract the single dense ffn counted in base, add k routed experts + router
    return (
        base
        - cfg.num_layers * per_expert
        + cfg.num_layers * (cfg.experts_per_token * per_expert + d * cfg.num_experts)
    )
