"""deepseek-coder-33b — dense 62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.

llama-arch [arXiv:2401.14196; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    source="arXiv:2401.14196; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, d_ff=160, vocab_size=256
)
