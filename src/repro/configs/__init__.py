from repro.configs.base import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    active_param_count,
    param_count,
    tokens_per_step,
)
from repro.configs.registry import ALL_IDS, ARCH_IDS, all_configs, get_config

__all__ = [
    "ModelConfig",
    "RunConfig",
    "ShapeConfig",
    "SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "ARCH_IDS",
    "ALL_IDS",
    "get_config",
    "all_configs",
    "param_count",
    "active_param_count",
    "tokens_per_step",
]
