from repro.distributed.chaos import (ChaosConfig, ChaosError, ChaosMonkey,
                                     TransientStepError)
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               RestartManifest,
                                               StragglerMonitor)
from repro.distributed.pipeline import bubble_fraction, pipelined_forward

__all__ = ["PreemptionHandler", "StragglerMonitor", "RestartManifest",
           "ChaosConfig", "ChaosError", "ChaosMonkey", "TransientStepError",
           "pipelined_forward", "bubble_fraction"]
