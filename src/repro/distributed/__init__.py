from repro.distributed.chaos import (ChaosConfig, ChaosError, ChaosMonkey,
                                     ShardChaosConfig, ShardChaosMonkey,
                                     ShardKilledError, TrainChaosConfig,
                                     TrainChaosMonkey, TrainStepCrashError,
                                     TransientStepError)
from repro.distributed.dispatcher import Dispatcher
from repro.distributed.fault_tolerance import (HealthMonitor,
                                               PreemptionHandler,
                                               RestartManifest, ShardState,
                                               StragglerMonitor)
from repro.distributed.pipeline import bubble_fraction, pipelined_forward

__all__ = ["PreemptionHandler", "StragglerMonitor", "RestartManifest",
           "HealthMonitor", "ShardState", "Dispatcher",
           "ChaosConfig", "ChaosError", "ChaosMonkey", "TransientStepError",
           "ShardChaosConfig", "ShardChaosMonkey", "ShardKilledError",
           "TrainChaosConfig", "TrainChaosMonkey", "TrainStepCrashError",
           "pipelined_forward", "bubble_fraction"]
