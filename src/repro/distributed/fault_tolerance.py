"""Fault tolerance: preemption, stragglers, elastic-restart manifest.

At 1000+ nodes the failure model is: (i) planned preemption (SIGTERM with a
grace window), (ii) hard node loss (step never completes), (iii) stragglers
(step completes but slowly). The three mechanisms here cover them:

* :class:`PreemptionHandler` — SIGTERM/SIGINT -> synchronous checkpoint at
  the next step boundary, then clean exit (requeue-able).
* :class:`StragglerMonitor` — per-step wall-time EMA; steps slower than
  ``threshold x`` EMA are flagged. On a real fleet the flag feeds the
  controller that cordons the slow host and triggers an elastic restart
  without it; here it logs and records into the manifest.
* :class:`RestartManifest` — tiny JSON (step, mesh shape, data cursor,
  checkpoint path). Because checkpoints are layout-agnostic (global arrays)
  and the data pipeline is ``batch(step)``-deterministic, a restart may use
  a *different* device count: the launcher re-plans shardings for the
  surviving mesh and resumes the exact token stream.
"""
from __future__ import annotations

import json
import os
import signal
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional


class PreemptionHandler:
    """Convert SIGTERM/SIGINT into a checkpoint-at-step-boundary request."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)
        self._prev.clear()

    def _handler(self, signum, frame):
        self.requested = True


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ema: float = 0.9,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.ema_coef = ema
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[Dict[str, float]] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> Optional[Dict[str, float]]:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        self.n += 1
        flag = None
        if self.ema is not None and self.n > self.warmup and \
                dt > self.threshold * self.ema:
            flag = {"step": step, "seconds": dt, "ema": self.ema}
            self.flagged.append(flag)
        self.ema = dt if self.ema is None else (
            self.ema_coef * self.ema + (1 - self.ema_coef) * dt)
        return flag


@dataclass
class RestartManifest:
    step: int
    checkpoint_dir: str
    mesh_shape: List[int]
    mesh_axes: List[str]
    data_seed: int
    arch: str = ""
    shape: str = ""
    straggler_events: List[Dict[str, float]] = field(default_factory=list)
    # Serving checkpoint (``ServeEngine.snapshot()``): queued + in-flight
    # request state and the engine/env config needed to re-prefill and drain
    # to byte-identical greedy completions after a preemption. ``None`` for
    # training manifests.
    serve: Optional[Dict[str, Any]] = None

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(asdict(self), f)
        os.rename(tmp, path)

    @classmethod
    def load(cls, path: str) -> "RestartManifest":
        with open(path) as f:
            return cls(**json.load(f))
