"""Fault tolerance: preemption, stragglers, elastic-restart manifest.

At 1000+ nodes the failure model is: (i) planned preemption (SIGTERM with a
grace window), (ii) hard node loss (step never completes), (iii) stragglers
(step completes but slowly). The three mechanisms here cover them:

* :class:`PreemptionHandler` — SIGTERM/SIGINT -> synchronous checkpoint at
  the next step boundary, then clean exit (requeue-able).
* :class:`StragglerMonitor` — per-step wall-time EMA; steps slower than
  ``threshold x`` EMA are flagged. On a real fleet the flag feeds the
  controller that cordons the slow host and triggers an elastic restart
  without it; here it logs and records into the manifest.
* :class:`HealthMonitor` — the StragglerMonitor idea promoted to fleet
  scope: instead of timing one process's steps, it keeps a per-shard
  heartbeat ledger for a :class:`~repro.launch.fleet.ServeFleet`. A shard
  that answers a dispatch beats; one that misses ``miss_suspect``
  consecutive beats is SUSPECT (the dispatcher stops routing new work to
  it), ``miss_dead`` misses is DEAD (the fleet fails its work over to a
  survivor). A beat from a SUSPECT shard revives it — UPMEM-style fleets
  see transient rank stalls far more often than hard losses.
* :class:`RestartManifest` — tiny JSON (step, mesh shape, data cursor,
  checkpoint path). Because checkpoints are layout-agnostic (global arrays)
  and the data pipeline is ``batch(step)``-deterministic, a restart may use
  a *different* device count: the launcher re-plans shardings for the
  surviving mesh and resumes the exact token stream. ``save`` is atomic
  (tmp file + ``os.replace``): a SIGTERM or shard kill mid-save can never
  leave a torn manifest behind for the next restart to trip on.
"""
from __future__ import annotations

import enum
import json
import os
import signal
import time
from dataclasses import asdict, dataclass, field
from typing import Any, Callable, Dict, List, Optional


class PreemptionHandler:
    """Convert SIGTERM/SIGINT into a checkpoint-at-step-boundary request."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.requested = False
        self._prev = {}
        self._signals = signals

    def install(self) -> "PreemptionHandler":
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def uninstall(self) -> None:
        for s, h in self._prev.items():
            signal.signal(s, h)
        self._prev.clear()

    def _handler(self, signum, frame):
        self.requested = True


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ema: float = 0.9,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.ema_coef = ema
        self.warmup = warmup_steps
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[Dict[str, float]] = []
        self._t0: Optional[float] = None

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> Optional[Dict[str, float]]:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        self.n += 1
        flag = None
        if self.ema is not None and self.n > self.warmup and \
                dt > self.threshold * self.ema:
            flag = {"step": step, "seconds": dt, "ema": self.ema}
            self.flagged.append(flag)
        self.ema = dt if self.ema is None else (
            self.ema_coef * self.ema + (1 - self.ema_coef) * dt)
        return flag


class ShardState(str, enum.Enum):
    """Failure-domain state of one fleet shard (see :class:`HealthMonitor`).

    LIVE shards take new work; SUSPECT shards keep their in-flight work but
    receive no new routing until they beat again; DEAD is sticky — the
    fleet has already failed the shard's work over, so a late reply from a
    zombie shard must never resurrect it.
    """

    LIVE = "live"
    SUSPECT = "suspect"
    DEAD = "dead"

    def __str__(self) -> str:
        return self.value


class HealthMonitor:
    """Per-shard heartbeat ledger: miss-threshold -> suspect -> dead.

    The fleet calls :meth:`beat` when a shard answers a step dispatch (with
    its heartbeat flag set) and :meth:`miss` when it does not (timeout,
    stall, or a reply whose heartbeat was dropped). ``miss_suspect``
    consecutive misses quarantine routing; ``miss_dead`` misses declare the
    shard lost. :meth:`mark_dead` skips the escalation for unambiguous
    failures (process exit, closed pipe, raised kill). All transitions are
    appended to ``events`` for tests and the bench soak cell.
    """

    def __init__(self, n_shards: int, *, miss_suspect: int = 2,
                 miss_dead: int = 4):
        assert 0 < miss_suspect <= miss_dead
        self.miss_suspect, self.miss_dead = miss_suspect, miss_dead
        self.states = [ShardState.LIVE] * n_shards
        self.misses = [0] * n_shards
        self.beats = [0] * n_shards
        self.suspects = 0
        self.recoveries = 0
        self.deaths = 0
        self.events: List[Dict[str, Any]] = []

    def state(self, shard: int) -> ShardState:
        return self.states[shard]

    def alive(self, shard: int) -> bool:
        return self.states[shard] is not ShardState.DEAD

    @property
    def live_shards(self) -> List[int]:
        return [s for s, st in enumerate(self.states)
                if st is ShardState.LIVE]

    @property
    def dead_shards(self) -> List[int]:
        return [s for s, st in enumerate(self.states)
                if st is ShardState.DEAD]

    def beat(self, shard: int, step: int) -> ShardState:
        """A heartbeat arrived; a SUSPECT shard recovers to LIVE."""
        if self.states[shard] is ShardState.DEAD:
            return ShardState.DEAD                 # zombies stay dead
        self.beats[shard] += 1
        self.misses[shard] = 0
        if self.states[shard] is ShardState.SUSPECT:
            self.states[shard] = ShardState.LIVE
            self.recoveries += 1
            self.events.append({"kind": "recover", "shard": shard,
                                "step": step})
        return self.states[shard]

    def miss(self, shard: int, step: int) -> ShardState:
        """A heartbeat was missed; escalate suspect -> dead at thresholds."""
        if self.states[shard] is ShardState.DEAD:
            return ShardState.DEAD
        self.misses[shard] += 1
        if self.misses[shard] >= self.miss_dead:
            return self.mark_dead(shard, step,
                                  f"{self.misses[shard]} missed heartbeats")
        if (self.misses[shard] >= self.miss_suspect
                and self.states[shard] is ShardState.LIVE):
            self.states[shard] = ShardState.SUSPECT
            self.suspects += 1
            self.events.append({"kind": "suspect", "shard": shard,
                                "step": step, "misses": self.misses[shard]})
        return self.states[shard]

    def mark_dead(self, shard: int, step: int, reason: str) -> ShardState:
        if self.states[shard] is not ShardState.DEAD:
            self.states[shard] = ShardState.DEAD
            self.deaths += 1
            self.events.append({"kind": "dead", "shard": shard,
                                "step": step, "reason": reason})
        return ShardState.DEAD


@dataclass
class RestartManifest:
    step: int
    checkpoint_dir: str
    mesh_shape: List[int]
    mesh_axes: List[str]
    data_seed: int
    arch: str = ""
    shape: str = ""
    straggler_events: List[Dict[str, float]] = field(default_factory=list)
    # Serving checkpoint (``ServeEngine.snapshot()``): queued + in-flight
    # request state and the engine/env config needed to re-prefill and drain
    # to byte-identical greedy completions after a preemption. ``None`` for
    # training manifests.
    serve: Optional[Dict[str, Any]] = None
    # Training loop state (``launch/train.py``'s ``loop_state``): data salt,
    # loss EWMA, skip/rollback counters, RNG key — the same payload the
    # checkpoint ``extra`` carries, mirrored here so a restart controller
    # can inspect it without opening the checkpoint. ``None`` for serving
    # manifests.
    train: Optional[Dict[str, Any]] = None

    def save(self, path: str) -> None:
        """Atomically persist: write ``path + ".tmp"``, fsync, then
        ``os.replace``. A crash mid-save leaves either the previous manifest
        or none — never a torn file — and the orphaned tmp is removed on the
        failure path so a retry starts clean."""
        tmp = path + ".tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(asdict(self), f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "RestartManifest":
        with open(path) as f:
            return cls(**json.load(f))
