"""Pipeline parallelism over the 'pod' axis (GPipe-style, shard_map+ppermute).

The MIMDRAM segment story applied across pods: each pod is a segment running
a different *stage program* (true MIMD at pod granularity), activations flow
stage-to-stage over the inter-pod links via ``collective_permute``, and
microbatches fill the pipeline (bubble fraction (P-1)/(P-1+M)).

This is the optional ``--pipeline`` path for multi-pod training of deep
stacks: stage s owns layers [s*L/P, (s+1)*L/P); within a stage, the usual
planner distribution (FSDP/TP) applies on the data/model axes (partial-auto
shard_map: only the pod axis is manual here).

Self-contained: any per-layer block function ``block_fn(params_l, x) -> x``
works; correctness is tested against the sequential stack in
tests/distributed_worker.py (mode: pipeline).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def _stage_slice(params_stacked: Any, stage: jax.Array, layers_per_stage: int):
    """Slice this stage's layer block out of (L, ...) stacked params."""
    return jax.tree_util.tree_map(
        lambda a: jax.lax.dynamic_slice_in_dim(
            a, stage * layers_per_stage, layers_per_stage, axis=0),
        params_stacked)


def pipelined_forward(
    block_fn: Callable[[Any, jax.Array], jax.Array],
    params_stacked: Any,
    x: jax.Array,                       # (M, mb, ...) microbatched input
    *,
    mesh: Mesh,
    n_stages: int,
    n_layers: int,
    pod_axis: str = "pod",
) -> jax.Array:
    """Run a layer stack as an n_stages pipeline over ``pod_axis``.

    x carries M microbatches; returns the stack output in the same layout.
    Schedule: M + n_stages - 1 ticks; at each tick a stage applies its
    layers to the activation it holds, then shifts it to the next stage.
    """
    assert n_layers % n_stages == 0
    lps = n_layers // n_stages
    M = x.shape[0]

    def per_stage(params_all, xs, stage_ids):
        # stage id arrives as a pod-sharded input rather than
        # lax.axis_index: under a partial-manual map, 0.4.x lowers
        # axis_index to a bare PartitionId the SPMD partitioner rejects.
        stage = stage_ids[0]
        my_params = _stage_slice(params_all, stage, lps)
        n_ticks = M + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def run_block(act):
            def body(h, layer_p):
                return block_fn(layer_p, h), None
            out, _ = jax.lax.scan(body, act, my_params)
            return out

        def tick(carry, t):
            acc, cur = carry
            # stage 0 feeds a fresh microbatch while any remain
            mb_idx = jnp.clip(t, 0, M - 1)
            fresh = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, keepdims=False)
            cur = jnp.where(stage == 0,
                            jnp.where(t < M, fresh, cur), cur)
            cur = run_block(cur)
            # last stage retires microbatch t-(P-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            retire = (stage == n_stages - 1) & (t >= n_stages - 1)
            acc = jnp.where(
                retire,
                jax.lax.dynamic_update_index_in_dim(acc, cur, out_idx, 0),
                acc)
            # shift activations to the next stage
            cur = jax.lax.ppermute(cur, pod_axis, perm)
            return (acc, cur), None

        acc0 = jnp.zeros_like(xs)
        cur0 = jnp.zeros_like(xs[0])
        (acc, _), _ = jax.lax.scan(tick, (acc0, cur0),
                                   jnp.arange(n_ticks, dtype=jnp.int32))
        # only the last stage holds results; psum replicates them pod-wide
        return jax.lax.psum(acc, pod_axis)

    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(), P(), P(pod_axis)),  # params + acts replicated on pod
        out_specs=P(),
        axis_names=frozenset({pod_axis}), check_vma=False)
    stage_ids = jnp.arange(mesh.shape[pod_axis], dtype=jnp.int32)
    return fn(params_stacked, x, stage_ids)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """GPipe bubble overhead: (P-1)/(P-1+M)."""
    return (n_stages - 1) / (n_stages - 1 + n_microbatches)
