"""Deterministic fault injection for the serving engine.

Real-hardware PIM studies (arXiv:2105.03814, arXiv:2205.14647) find that
moving from simulation to deployed memory-centric systems is dominated by
operational failure modes, not kernel math. This module makes those failure
modes reproducible: a seeded :class:`ChaosMonkey` injects

* **non-finite logits** — armed per (slot, position) through the fused
  scan's ``logits_hook`` (see :func:`nan_logits_hook`), so the poison
  appears exactly where a real activation overflow would, inside the jit;
* **slow chunks** — a host-side sleep before a chunk dispatch, exercising
  the StragglerMonitor watchdog and load shedding;
* **transient step failures** — :class:`TransientStepError` raised *before*
  the dispatch (a retry must never re-dispatch donated buffers), exercising
  the engine's retry-with-backoff path;
* **page-pool pressure** — physical pages stolen from the allocator's free
  list, exercising admission backpressure and the typed exhaustion error.

Every decision is drawn from ``numpy.random.default_rng(seed)`` and cached
per injection site, so a drain with the same seed replays the same faults —
including across an engine retry of the same chunk index (fire-once
semantics). ``ChaosConfig.from_env()`` parses the ``REPRO_CHAOS`` knob
(e.g. ``REPRO_CHAOS="seed=7,nan=1,slow=2,fail=1,pages=4"``) so CI smokes
and the bench soak cell can arm injection without code changes.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CHAOS_ENV = "REPRO_CHAOS"


class ChaosError(RuntimeError):
    """Base class for injected faults."""


class TransientStepError(ChaosError):
    """Injected transient chunk-dispatch failure (succeeds on retry)."""


class ShardKilledError(ChaosError):
    """Injected hard shard loss: an in-process fleet shard raises this from
    its next step (the multiprocessing backend gets a real SIGKILL via
    ``Process.terminate`` instead — both surface as an unambiguous death to
    the fleet's :class:`~repro.distributed.fault_tolerance.HealthMonitor`).
    """


def nan_logits_hook(logits, row_pos, arm):
    """Trace-time NaN injection for ``make_generate_step(logits_hook=...)``.

    ``row_pos`` (B, S) is the absolute cache position of each logits row;
    ``arm`` (B,) holds the poison position per slot (-1 = disarmed). Rows
    whose position equals the armed position go NaN; all other rows pass
    through bitwise-unchanged (``jnp.where`` with a false mask is identity),
    so disarmed slots decode byte-identically to an unhooked program.
    """
    hit = (arm[:, None] >= 0) & (row_pos == arm[:, None])
    return jnp.where(hit[..., None], jnp.nan, logits)


@dataclass
class ChaosConfig:
    """Seeded fault-injection plan.

    ``nan``/``slow``/``fail``/``pages`` are budgets: how many requests get
    poisoned logits, how many chunks are slowed/failed, how many physical
    pages are stolen. ``nan_targets`` / ``slow_chunks`` / ``fail_chunks``
    are explicit overrides for deterministic tests (uid -> generated-token
    index, and chunk indices respectively); when set they replace the
    corresponding seeded draw.
    """

    seed: int = 0
    nan: int = 0
    slow: int = 0
    fail: int = 0
    pages: int = 0
    slow_ms: float = 25.0
    steal_after_chunk: int = 1
    nan_targets: Optional[Dict[int, int]] = None
    slow_chunks: Optional[Sequence[int]] = None
    fail_chunks: Optional[Sequence[int]] = None

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "ChaosConfig":
        """Parse ``"nan=1,slow=2,fail=1,pages=4,slow_ms=25,seed=7"``."""
        kw: Dict[str, Any] = {"seed": seed}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in ("seed", "nan", "slow", "fail", "pages", "slow_ms",
                         "steal_after_chunk"):
                raise ValueError(f"{CHAOS_ENV}: unknown chaos knob {k!r}")
            kw[k] = float(v) if k == "slow_ms" else int(v)
        return cls(**kw)

    @classmethod
    def from_env(cls, seed: Optional[int] = None) -> Optional["ChaosConfig"]:
        spec = os.environ.get(CHAOS_ENV, "")
        if not spec:
            return None
        return cls.parse(spec, seed=0 if seed is None else seed)

    @property
    def wants_nan(self) -> bool:
        return self.nan > 0 or bool(self.nan_targets)


class ChaosMonkey:
    """Executes a :class:`ChaosConfig` against one engine drain.

    The engine calls :meth:`plan_request` at admit time (arming NaN
    injection), :meth:`on_chunk` immediately before each fused-chunk
    dispatch (sleep / raise), and :meth:`page_pressure` between chunks
    (steal pages). All decisions are cached per injection site and fire at
    most once, so a chunk retried after an injected failure replays clean —
    deterministic under the engine's retry loop.
    """

    def __init__(self, cfg: ChaosConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.events: List[Dict[str, Any]] = []
        self._nan_left = cfg.nan
        self._slow_left = cfg.slow
        self._fail_left = cfg.fail
        self._chunk_plan: Dict[int, Tuple[bool, bool]] = {}
        self._fired_slow: set = set()
        self._fired_fail: set = set()
        self.held_pages: List[int] = []

    # -- NaN logits ---------------------------------------------------------
    def plan_request(self, uid: int, prompt_len: int,
                     max_new: int) -> Optional[int]:
        """Absolute cache position to poison for this request (None = clean).

        A poisoned request loses only tokens after the armed position: the
        scan's finite guard quarantines the slot with ``g + 1`` tokens when
        position ``prompt_len + g`` is armed.
        """
        if self.cfg.nan_targets is not None:
            g = self.cfg.nan_targets.get(uid)
            if g is None:
                return None
            pos = prompt_len + int(g)
        else:
            if self._nan_left <= 0:
                return None
            if self.rng.random() >= 0.5:
                return None
            self._nan_left -= 1
            pos = prompt_len + int(self.rng.integers(0, max(max_new - 1, 1)))
        self.events.append({"kind": "nan", "uid": uid, "pos": pos})
        return pos

    # -- slow / failing chunks ---------------------------------------------
    def _plan_chunk(self, idx: int) -> Tuple[bool, bool]:
        if idx not in self._chunk_plan:
            if self.cfg.slow_chunks is not None:
                slow = idx in self.cfg.slow_chunks
            else:
                slow = self._slow_left > 0 and self.rng.random() < 0.5
            if self.cfg.fail_chunks is not None:
                fail = idx in self.cfg.fail_chunks
            else:
                fail = self._fail_left > 0 and self.rng.random() < 0.4
            if slow and self.cfg.slow_chunks is None:
                self._slow_left -= 1
            if fail and self.cfg.fail_chunks is None:
                self._fail_left -= 1
            self._chunk_plan[idx] = (slow, fail)
        return self._chunk_plan[idx]

    def on_chunk(self, idx: int) -> None:
        """Called before dispatching chunk ``idx``; may sleep or raise.

        Raises happen *before* the dispatch so the engine's retry never
        replays a jit whose donated operands are already consumed.
        """
        slow, fail = self._plan_chunk(idx)
        if slow and idx not in self._fired_slow:
            self._fired_slow.add(idx)
            self.events.append({"kind": "slow", "chunk": idx,
                                "ms": self.cfg.slow_ms})
            time.sleep(self.cfg.slow_ms / 1e3)
        if fail and idx not in self._fired_fail:
            self._fired_fail.add(idx)
            self.events.append({"kind": "fail", "chunk": idx})
            raise TransientStepError(
                f"injected transient failure at chunk {idx} "
                f"(seed={self.cfg.seed})")

    # -- page-pool pressure -------------------------------------------------
    # (shard-level faults live in ShardChaosConfig / ShardChaosMonkey below —
    # this class injects *inside* one engine, those kill whole shards)
    def page_pressure(self, alloc, idx: int) -> None:
        """Steal ``cfg.pages`` physical pages from ``alloc``'s free list
        once, after ``steal_after_chunk`` chunks have dispatched."""
        if self.cfg.pages <= 0 or self.held_pages or \
                idx < self.cfg.steal_after_chunk:
            return
        steal = min(self.cfg.pages, len(alloc.free))
        self.held_pages = [alloc.free.pop() for _ in range(steal)]
        self.events.append({"kind": "pages", "chunk": idx,
                            "stolen": len(self.held_pages)})

    def release_pages(self, alloc) -> None:
        alloc.free.extend(self.held_pages)
        self.held_pages = []


# ---------------------------------------------------------------------------
# Shard-level faults (the fleet failure domain)
# ---------------------------------------------------------------------------
@dataclass
class ShardChaosConfig:
    """Seeded shard-level fault plan for a ``ServeFleet`` drain.

    Three fault kinds, mirroring what UPMEM-scale deployments actually see
    from independent ranks (arXiv:2105.03814):

    * **kill** — hard shard loss: the in-process shard raises
      :class:`ShardKilledError`; the multiprocessing shard is
      ``terminate()``-d. Unambiguous death -> immediate failover.
    * **stall** — the shard hangs: it stops stepping *and* heartbeating for
      ``stall_steps`` fleet steps (default: forever), so the HealthMonitor
      must walk the miss -> suspect -> dead escalation before failover.
    * **drop** — heartbeats are dropped for ``drop_beats`` steps while the
      shard keeps working: exercises suspect -> recover without failover.

    ``kill_targets`` / ``stall_targets`` / ``drop_targets`` map
    ``shard -> fleet step`` for deterministic tests; the ``kill`` /
    ``stall`` / ``drop`` budgets instead draw distinct (shard, step) pairs
    from the seed at :class:`ShardChaosMonkey` construction. Every fault
    fires at most once per shard (fire-once), so a drain with the same seed
    replays the same faults.

    :meth:`parse` accepts the CLI/env spelling used by ``--fleet-chaos``:
    explicit targets ``kill=SHARD@STEP`` (``drop=SHARD@STEPxBEATS`` adds a
    beat count) and seeded budgets ``kills=N,stalls=N,drops=N``, e.g.
    ``"kill=1@2"`` or ``"seed=7,kills=1,drops=1"``.
    """

    seed: int = 0
    kill: int = 0
    stall: int = 0
    drop: int = 0
    after_step: int = 1           # earliest step for seeded draws
    window: int = 4               # seeded steps land in [after, after+window)
    stall_steps: int = 1 << 30    # a stall is a hang unless bounded
    drop_beats: int = 2
    kill_targets: Optional[Dict[int, int]] = None
    stall_targets: Optional[Dict[int, int]] = None
    drop_targets: Optional[Dict[int, Tuple[int, int]]] = None  # sid->(step,n)

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "ShardChaosConfig":
        """Parse ``"kill=1@2,stall=0@4,drop=1@3x2,kills=1,seed=7"``."""
        kw: Dict[str, Any] = {"seed": seed}
        budgets = {"kills": "kill", "stalls": "stall", "drops": "drop"}
        ints = ("seed", "after_step", "window", "stall_steps", "drop_beats")
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k = k.strip()
            if k in budgets:
                kw[budgets[k]] = int(v)
            elif k in ints:
                kw[k] = int(v)
            elif k in ("kill", "stall"):
                sid, _, step = v.partition("@")
                tgt = kw.setdefault(k + "_targets", {})
                tgt[int(sid)] = int(step or 1)
            elif k == "drop":
                sid, _, rest = v.partition("@")
                step, _, beats = rest.partition("x")
                tgt = kw.setdefault("drop_targets", {})
                tgt[int(sid)] = (int(step or 1), int(beats or 2))
            else:
                raise ValueError(f"--fleet-chaos: unknown shard fault {k!r}")
        return cls(**kw)

    @property
    def armed(self) -> bool:
        return bool(self.kill or self.stall or self.drop or self.kill_targets
                    or self.stall_targets or self.drop_targets)


class ShardChaosMonkey:
    """Executes a :class:`ShardChaosConfig` against one fleet drain.

    The fleet calls :meth:`directive` for every (shard, fleet step) before
    dispatching that shard's step; the returned directive (or None) tells
    the shard handle what to inject. Seeded budget draws are fixed at
    construction (distinct shards, steps in the config window) so the plan
    is a pure function of (seed, n_shards) — deterministic and fire-once,
    exactly like the engine-level :class:`ChaosMonkey`.
    """

    def __init__(self, cfg: ShardChaosConfig, n_shards: int):
        self.cfg = cfg
        self.events: List[Dict[str, Any]] = []
        rng = np.random.default_rng(cfg.seed)
        self._plan: Dict[Tuple[int, int], Dict[str, Any]] = {}

        def seed_draws(kind: str, budget: int, extra=None) -> None:
            picks = rng.choice(n_shards, size=min(budget, n_shards),
                               replace=False) if budget else []
            for sid in picks:
                step = int(cfg.after_step + rng.integers(0, max(cfg.window,
                                                                1)))
                self._add(kind, int(sid), step, extra)

        for sid, step in (cfg.kill_targets or {}).items():
            self._add("kill", sid, step, None)
        for sid, step in (cfg.stall_targets or {}).items():
            self._add("stall", sid, step, {"steps": cfg.stall_steps})
        for sid, (step, beats) in (cfg.drop_targets or {}).items():
            self._add("drop", sid, step, {"beats": beats})
        seed_draws("kill", cfg.kill)
        seed_draws("stall", cfg.stall, {"steps": cfg.stall_steps})
        seed_draws("drop", cfg.drop, {"beats": cfg.drop_beats})

    def _add(self, kind: str, sid: int, step: int, extra) -> None:
        d = {"kind": kind, "shard": sid, "step": step}
        if extra:
            d.update(extra)
        self._plan.setdefault((sid, step), d)

    def directive(self, shard: int, step: int) -> Optional[Dict[str, Any]]:
        """Fault to inject into ``shard`` at fleet ``step`` (fire-once)."""
        d = self._plan.pop((shard, step), None)
        if d is not None:
            self.events.append(dict(d))
        return d


# ---------------------------------------------------------------------------
# Train-loop faults (the training failure domain)
# ---------------------------------------------------------------------------
class TrainStepCrashError(ChaosError):
    """Injected hard train-step failure, raised on the host *before* the
    dispatch — the training analogue of a node loss. The
    ``TrainSupervisor``'s bounded restart budget absorbs it by resuming from
    the last verified checkpoint."""


def nan_grad_hook(loss, grads, arm):
    """Trace-time NaN-gradient injection for
    ``make_train_step(grad_hook=...)`` — the ``logits_hook`` pattern applied
    to training. ``arm`` is a traced int32 scalar: nonzero poisons every
    floating-point gradient leaf with NaN so the step's non-finite guard
    must skip the update; a disarmed dispatch passes through
    bitwise-unchanged (``jnp.where`` with a false predicate is identity), so
    one compiled program serves clean and poisoned steps.
    """
    bad = arm > 0
    poisoned = jax.tree_util.tree_map(
        lambda g: jnp.where(bad, jnp.full_like(g, jnp.nan), g)
        if jnp.issubdtype(g.dtype, jnp.floating) else g, grads)
    return loss, poisoned


TRAIN_CHAOS_KNOBS = ("seed", "nan", "slow", "spike", "crash", "ckpt_fail",
                     "torn", "preempt", "slow_ms", "spike_x", "after_step",
                     "window")


@dataclass
class TrainChaosConfig:
    """Seeded fault plan for one training run.

    Budgets: ``nan`` steps get NaN gradients (through the compiled guard),
    ``slow`` steps sleep ``slow_ms`` before dispatch, ``spike`` steps have
    their *observed* loss scaled by ``spike_x`` (tripping the EWMA anomaly
    detector and its rollback), ``crash`` steps raise
    :class:`TrainStepCrashError` on the host, ``ckpt_fail`` checkpoint
    writes fail mid-save, and ``torn`` checkpoints are truncated *after* a
    successful save (corruption the atomic rename can't prevent — media
    rot). ``preempt=N`` requests a clean preemption at step ``N``.

    Seeded budget draws land on distinct steps in
    ``[after_step, after_step + window)``; the ``*_steps`` fields are
    explicit overrides for deterministic tests. ``ckpt_fail_steps`` /
    ``torn_steps`` are *thresholds*: each arms the first checkpoint written
    at-or-after that step. Everything is resolved at
    :class:`TrainChaosMonkey` construction as a pure function of the config,
    so a rolled-back or resumed window re-arms the same absolute steps —
    exactly what the bitwise resume-identity gate needs. Spikes additionally
    fire only in the original data window (``salt == 0``), so a rollback's
    re-seeded replay cannot re-trip the detector forever.
    """

    seed: int = 0
    nan: int = 0
    slow: int = 0
    spike: int = 0
    crash: int = 0
    ckpt_fail: int = 0
    torn: int = 0
    preempt: int = -1
    slow_ms: float = 25.0
    spike_x: float = 50.0
    after_step: int = 1
    window: int = 8
    nan_steps: Optional[Sequence[int]] = None
    slow_steps: Optional[Sequence[int]] = None
    spike_steps: Optional[Sequence[int]] = None
    crash_steps: Optional[Sequence[int]] = None
    ckpt_fail_steps: Optional[Sequence[int]] = None
    torn_steps: Optional[Sequence[int]] = None

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "TrainChaosConfig":
        """Parse ``"nan=2,slow=1,spike=1,preempt=11,seed=7"``."""
        kw: Dict[str, Any] = {"seed": seed}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, _, v = part.partition("=")
            k = k.strip()
            if k not in TRAIN_CHAOS_KNOBS:
                raise ValueError(f"{CHAOS_ENV}: unknown train chaos knob "
                                 f"{k!r}")
            kw[k] = float(v) if k in ("slow_ms", "spike_x") else int(v)
        return cls(**kw)

    @classmethod
    def from_env(cls, seed: Optional[int] = None
                 ) -> Optional["TrainChaosConfig"]:
        spec = os.environ.get(CHAOS_ENV, "")
        if not spec:
            return None
        return cls.parse(spec, seed=0 if seed is None else seed)

    @property
    def wants_nan(self) -> bool:
        return self.nan > 0 or bool(self.nan_steps)


class TrainChaosMonkey:
    """Executes a :class:`TrainChaosConfig` against one *supervised* run.

    The driver calls :meth:`nan_armed` when building each dispatch's ``arm``
    operand, :meth:`on_step` before the dispatch (sleep / raise),
    :meth:`loss_scale` when feeding the anomaly detector, :meth:`preempt`
    at each step boundary, and wires :meth:`ckpt_fault` into the
    ``CheckpointManager`` as its ``fault_hook``; :meth:`maybe_tear`
    truncates a just-written checkpoint.

    Per-step data faults (nan/slow/spike) are pure functions of the
    absolute step, so a replayed window injects identically — that keeps
    interrupted+resumed runs bitwise-equal to uninterrupted ones.
    Operational faults (crash/ckpt_fail/torn/preempt) are fire-once per
    monkey; the ``TrainSupervisor`` shares ONE monkey across its restart
    attempts, so "the machine was preempted at step 11" happens once per
    supervised run, like a real incident.
    """

    def __init__(self, cfg: TrainChaosConfig, total_steps: int):
        self.cfg = cfg
        self.events: List[Dict[str, Any]] = []
        rng = np.random.default_rng(cfg.seed)
        hi = max(total_steps, cfg.after_step + 1)

        def draw(budget: int, explicit) -> List[int]:
            if explicit is not None:
                return sorted(int(s) for s in explicit)
            if budget <= 0:
                return []
            lo = min(cfg.after_step, hi - 1)
            span = max(min(cfg.after_step + cfg.window, hi) - lo, 1)
            picks = rng.choice(span, size=min(budget, span), replace=False)
            return sorted(int(lo + s) for s in picks)

        self.nan_steps = set(draw(cfg.nan, cfg.nan_steps))
        self.slow_steps = set(draw(cfg.slow, cfg.slow_steps))
        self.spike_steps = set(draw(cfg.spike, cfg.spike_steps))
        self.crash_steps = set(draw(cfg.crash, cfg.crash_steps))
        self._ckpt_fail = draw(cfg.ckpt_fail, cfg.ckpt_fail_steps)
        self._torn = draw(cfg.torn, cfg.torn_steps)
        self._fired_slow: set = set()
        self._fired_crash: set = set()
        self._preempt_armed = cfg.preempt >= 0

    # -- per-step data faults (pure in the absolute step) -------------------
    def nan_armed(self, step: int) -> bool:
        if step in self.nan_steps:
            self.events.append({"kind": "nan", "step": step})
            return True
        return False

    def loss_scale(self, step: int, salt: int = 0) -> float:
        """Observed-loss multiplier feeding the spike detector. Fires only
        in the original data window (``salt == 0``): a rollback re-seeds the
        window precisely so the replay does not re-trip."""
        if salt == 0 and step in self.spike_steps:
            self.events.append({"kind": "spike", "step": step,
                                "x": self.cfg.spike_x})
            return self.cfg.spike_x
        return 1.0

    # -- operational faults (fire-once per monkey) --------------------------
    def on_step(self, step: int) -> None:
        """Called before dispatching ``step``; may sleep or raise. Raises
        happen before the dispatch so donated buffers are never consumed by
        a step the supervisor will replay."""
        if step in self.slow_steps and step not in self._fired_slow:
            self._fired_slow.add(step)
            self.events.append({"kind": "slow", "step": step,
                                "ms": self.cfg.slow_ms})
            time.sleep(self.cfg.slow_ms / 1e3)
        if step in self.crash_steps and step not in self._fired_crash:
            self._fired_crash.add(step)
            self.events.append({"kind": "crash", "step": step})
            raise TrainStepCrashError(
                f"injected hard step failure at step {step} "
                f"(seed={self.cfg.seed})")

    def preempt(self, step: int) -> bool:
        if self._preempt_armed and step >= self.cfg.preempt:
            self._preempt_armed = False
            self.events.append({"kind": "preempt", "step": step})
            return True
        return False

    def ckpt_fault(self, step: int, key: str) -> None:
        """``CheckpointManager`` fault hook: the first checkpoint written
        at-or-after each armed threshold fails on its first leaf."""
        for i, thr in enumerate(self._ckpt_fail):
            if step >= thr:
                del self._ckpt_fail[i]
                self.events.append({"kind": "ckpt_fail", "step": step,
                                    "leaf": key})
                raise OSError(f"injected checkpoint write failure at step "
                              f"{step} (seed={self.cfg.seed})")

    def maybe_tear(self, manager, step: int) -> None:
        """After a completed save of ``step``: truncate one leaf file,
        simulating corruption the atomic rename cannot prevent. ``restore``
        must detect the bad CRC and fall back to the previous checkpoint."""
        for i, thr in enumerate(self._torn):
            if step >= thr:
                del self._torn[i]
                manager.wait()
                path = os.path.join(manager.dir, f"step_{step:08d}")
                leaves = sorted(f for f in os.listdir(path)
                                if f.endswith(".npy"))
                if not leaves:
                    return
                target = os.path.join(path, leaves[0])
                size = os.path.getsize(target)
                with open(target, "r+b") as f:
                    f.truncate(max(size // 2, 1))
                self.events.append({"kind": "torn", "step": step,
                                    "leaf": leaves[0]})
                return
