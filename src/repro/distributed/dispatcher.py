"""Fleet dispatcher: health-checked least-loaded routing over engine shards.

The HBM-PIMulator idiom — one controller per memory channel behind a single
``send/tick`` facade — maps onto serving as N ``ServeEngine`` shards behind
one :class:`~repro.launch.fleet.ServeFleet`. This module is the routing
brain of that facade: pure bookkeeping (which request lives on which shard,
how loaded each shard is, who is allowed to take new work), deliberately
free of any JAX import so the control plane stays version-agnostic and
picklable-adjacent (the CI lint in ``tools/check_jax_compat.py`` enforces
the no-``jax``-import rule for this module and ``launch/fleet.py``).

Routing policy: among LIVE shards, pick the one with the fewest in-flight
requests, breaking ties by fewest reserved KV pages (the shard-local
admission reservation that :class:`~repro.launch.engine.ServeEngine`
maintains), then by shard index for determinism. SUSPECT shards keep their
in-flight work but receive no new routing; if *no* LIVE shard exists the
dispatcher degrades to SUSPECT shards (better a slow shard than a dropped
request) and returns ``None`` only when every shard is DEAD — at which
point the fleet must emit a typed ``shard_lost`` error completion.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.distributed.fault_tolerance import HealthMonitor, ShardState


class Dispatcher:
    """Assigns request uids to shards under health + load constraints."""

    def __init__(self, monitor: HealthMonitor):
        self.monitor = monitor
        n = len(monitor.states)
        self.assigned: List[set] = [set() for _ in range(n)]
        self.reserved: List[int] = [0] * n
        self.routed = 0
        self._home: Dict[int, int] = {}

    # -- load signals -------------------------------------------------------
    def note_reserved(self, shard: int, pages: int) -> None:
        """Refresh the KV-page reservation signal for one shard (reported
        back with every step heartbeat)."""
        self.reserved[shard] = int(pages)

    def load(self, shard: int) -> int:
        return len(self.assigned[shard])

    # -- routing ------------------------------------------------------------
    def route(self, exclude=()) -> Optional[int]:
        """Least-loaded routable shard, or ``None`` if the fleet is dead
        (``exclude``: shards currently unavailable, e.g. mid-step)."""
        for pool in (ShardState.LIVE, ShardState.SUSPECT):
            cands = [s for s, st in enumerate(self.monitor.states)
                     if st is pool and s not in exclude]
            if cands:
                best = min(cands, key=lambda s: (len(self.assigned[s]),
                                                 self.reserved[s], s))
                return best
        return None

    def assign(self, uid: int, shard: int) -> None:
        self.assigned[shard].add(uid)
        self._home[uid] = shard
        self.routed += 1

    def home(self, uid: int) -> Optional[int]:
        return self._home.get(uid)

    def complete(self, uid: int) -> None:
        """A completion for ``uid`` was drained; drop its load accounting."""
        shard = self._home.pop(uid, None)
        if shard is not None:
            self.assigned[shard].discard(uid)

    def fail_shard(self, shard: int) -> List[int]:
        """The shard is dead: return its outstanding uids (sorted for
        deterministic replay order) and clear their assignment so failover
        can re-route them."""
        uids = sorted(self.assigned[shard])
        self.assigned[shard] = set()
        for uid in uids:
            self._home.pop(uid, None)
        return uids

    @property
    def outstanding(self) -> int:
        return len(self._home)
