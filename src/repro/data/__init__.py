from repro.data.pipeline import (SyntheticLMDataset, make_batch_fn,
                                 pack_documents)

__all__ = ["SyntheticLMDataset", "make_batch_fn", "pack_documents"]
