"""Data pipeline: deterministic synthetic LM corpus + document packing.

Determinism contract (fault-tolerance substrate): batch(step) is a pure
function of (seed, step, global shape) — a restarted or re-sharded job
resumes the exact token stream from the checkpointed step, and a straggler
replacement host can recompute any shard independently (no data server
round-trip). This is the data-side half of elastic restart.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclass
class SyntheticLMDataset:
    """Zipf-unigram + order-1 Markov synthetic language.

    Has learnable structure (bigram transitions) so example training runs
    show honest loss decrease below the unigram entropy floor.
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # zipf unigram over vocab
        ranks = np.arange(1, V + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # low-rank markov structure: state -> preferred token band
        self.state_of = rng.integers(0, self.n_states, size=V)
        self.next_state = rng.permutation(self.n_states)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) & 0x7FFFFFFF)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(0, self.n_states, size=B)
        band = max(V // self.n_states, 1)
        for t in range(S):
            # with p=0.75 sample from the state's token band, else unigram
            use_band = rng.random(B) < 0.75
            band_tok = (cur * band + rng.integers(0, band, size=B)) % V
            uni_tok = rng.choice(V, size=B, p=self.unigram)
            toks[:, t] = np.where(use_band, band_tok, uni_tok)
            cur = self.next_state[self.state_of[toks[:, t]]]
        return {"tokens": toks, "labels": toks.copy()}


def pack_documents(docs: list, seq_len: int, eos: int = 0
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Pack variable-length documents into fixed rows; returns (tokens, mask).

    mask=0 at positions crossing a document boundary (no cross-doc loss).
    """
    rows, masks = [], []
    buf: list = []
    mbuf: list = []
    for doc in docs:
        for i, tok in enumerate(list(doc) + [eos]):
            buf.append(tok)
            mbuf.append(0 if i == len(doc) else 1)
            if len(buf) == seq_len:
                rows.append(buf)
                masks.append(mbuf)
                buf, mbuf = [], []
    if buf:
        pad = seq_len - len(buf)
        rows.append(buf + [eos] * pad)
        masks.append(mbuf + [0] * pad)
    return np.asarray(rows, np.int32), np.asarray(masks, np.float32)


def make_batch_fn(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    """Returns batch(step) -> dict of numpy arrays matching input_specs."""
    ds = SyntheticLMDataset(cfg.vocab_size, shape.seq_len, shape.global_batch,
                            seed)

    def fn(step: int) -> Dict[str, np.ndarray]:
        b = ds.batch(step)
        rng = np.random.default_rng(seed + 7 * step + 13)
        if cfg.family == "vlm":
            P = min(cfg.num_patches, shape.seq_len // 2)
            b["tokens"] = b["tokens"][:, : shape.seq_len - P]
            b["labels"] = b["labels"][:, : shape.seq_len - P]
            b["patch_embeds"] = rng.standard_normal(
                (shape.global_batch, P, cfg.d_model)).astype(np.float32)
        if cfg.family == "audio":
            src = int(shape.seq_len * cfg.src_len_ratio)
            b["src_embeds"] = rng.standard_normal(
                (shape.global_batch, src, cfg.d_model)).astype(np.float32)
        return b

    return fn
