"""Checkpointing: atomic step snapshots, async save, elastic reshard-on-load.

Layout:  <dir>/step_00000100/  leaf files `<flat-key>.npy` + manifest.json.
Writes go to a tmp dir renamed into place (atomic on POSIX), so a crash
mid-save never corrupts the latest checkpoint. Checkpoints store *global*
(unsharded) arrays; on restore, leaves are ``jax.device_put`` with whatever
sharding the (possibly different-sized) new mesh plan dictates — that is the
elastic-rescale path: save on 512 chips, resume on 256, or on CPU.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = leaf
    return flat


def _key_sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\-]", "_", key)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        # materialize on host before handing to the writer thread
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}))
            self._thread.start()
        else:
            self._write(step, host_tree, extra or {})

    def _write(self, step: int, host_tree: Any, extra: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in flat.items():
            fname = _key_sanitize(key) + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- load -----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``.

        ``shardings``: optional matching pytree of NamedSharding — the elastic
        path: leaves are placed directly with the *new* mesh layout.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        tree = load_checkpoint(os.path.join(self.dir, f"step_{step:08d}"),
                               template)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s) if s is not None else
                jax.device_put(a), tree, shardings)
        return step, tree


def load_checkpoint(path: str, template: Any) -> Any:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_meta = manifest["leaves"]
    paths = jax.tree_util.tree_leaves_with_path(template)
    vals = []
    for kpath, leaf in paths:
        key = jax.tree_util.keystr(kpath)
        if key not in leaves_meta:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, leaves_meta[key]["file"]))
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {expect}")
        vals.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)
