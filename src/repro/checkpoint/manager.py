"""Checkpointing: atomic, verified step snapshots, async save, elastic load.

Layout:  <dir>/step_00000100/  leaf files `<flat-key>.npy` + manifest.json.
Writes go to a tmp dir renamed into place (atomic on POSIX) with every leaf
file, the manifest, and the directory fsync'd first — matching
``RestartManifest.save`` — so a crash mid-save never corrupts the latest
checkpoint. The manifest records a per-leaf CRC32; ``restore`` verifies
shape, dtype, and checksum and *falls back to the previous checkpoint* (with
a warning) when the latest is torn or corrupt, so a bad write costs one
checkpoint interval, never the run. Async-writer exceptions are captured and
re-raised at the next ``save()``/``wait()`` instead of dying silently in the
thread.

Checkpoints store *global* (unsharded) arrays; on restore, leaves are
``jax.device_put`` with whatever sharding the (possibly different-sized) new
mesh plan dictates — that is the elastic-rescale path: save on 512 chips,
resume on 256, or on CPU.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import warnings
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class CheckpointError(RuntimeError):
    """Base class for typed checkpoint failures."""


class CheckpointWriteError(CheckpointError):
    """A checkpoint write failed (sync, or captured from the async writer
    and re-raised at the next ``save()``/``wait()``)."""


class CheckpointCorruptError(CheckpointError):
    """A checkpoint on disk is torn or corrupt: unreadable manifest/leaf,
    or a leaf whose CRC32/shape/dtype disagrees with its manifest entry."""


class CheckpointMismatchError(CheckpointError, ValueError):
    """The checkpoint is intact but does not match the restore *template*
    (missing leaf, or shape/dtype mismatch). Subclasses ``ValueError`` so
    pre-existing shape-mismatch handling keeps working."""


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = leaf
    return flat


def _key_sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\-]", "_", key)


def _crc(arr: np.ndarray) -> int:
    # tobytes() copies to C order itself; ascontiguousarray would promote
    # 0-d leaves (optimizer step counters) to shape (1,) on some numpys.
    return zlib.crc32(arr.tobytes())


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform without directory fds — rename is still atomic
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _rmtree_atomic(path: str, suffix: str) -> None:
    """Delete a checkpoint dir without ever exposing a half-deleted step:
    rename out of the ``step_NNN`` namespace first, then rmtree. A crash
    between the two leaves only a ``.trash``/``.old`` dir that ``all_steps``
    ignores and the next write sweeps."""
    side = path + suffix
    shutil.rmtree(side, ignore_errors=True)
    try:
        os.rename(path, side)
    except OSError:
        return
    shutil.rmtree(side, ignore_errors=True)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 fault_hook: Optional[Callable[[int, str], None]] = None):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        # chaos injection point: called as fault_hook(step, leaf_key) before
        # each leaf write; raising simulates a mid-save I/O failure.
        self.fault_hook = fault_hook
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             block: bool = False) -> None:
        # materialize on host before handing to the writer thread
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()  # re-raises a captured async-write failure
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host_tree, extra or {}))
            self._thread.start()
        else:
            try:
                self._write(step, host_tree, extra or {})
            except Exception as e:
                raise CheckpointWriteError(
                    f"checkpoint write for step {step} failed: {e}") from e

    def _write_guarded(self, step: int, host_tree: Any, extra: Dict) -> None:
        try:
            self._write(step, host_tree, extra)
        except BaseException as e:  # noqa: BLE001 — surfaced at next wait()
            self._error = e

    def _write(self, step: int, host_tree: Any, extra: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for key, arr in flat.items():
            if self.fault_hook is not None:
                self.fault_hook(step, key)
            arr = np.asarray(arr)
            fname = _key_sanitize(key) + ".npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "crc32": _crc(arr)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        if os.path.exists(final):
            # swap, never delete-then-rename: a crash in between must leave
            # either the old step (as .old, swept below) or the new one.
            old = final + ".old"
            shutil.rmtree(old, ignore_errors=True)
            os.rename(final, old)
            os.rename(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.rename(tmp, final)
        _fsync_dir(self.dir)
        self._gc()

    def wait(self) -> None:
        """Join the async writer; re-raise any failure it captured."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointWriteError(
                f"async checkpoint write failed: {err}") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            _rmtree_atomic(os.path.join(self.dir, f"step_{s:08d}"), ".trash")

    # -- load -----------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def load_extra(self, step: int) -> Dict[str, Any]:
        """The ``extra`` payload saved with ``step`` (loop state, loss, ...)."""
        path = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        try:
            with open(path) as f:
                return json.load(f).get("extra", {})
        except (OSError, json.JSONDecodeError) as e:
            raise CheckpointCorruptError(
                f"unreadable manifest for step {step}: {e}") from e

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``, verifying checksums.

        Without an explicit ``step``, candidates are tried newest -> oldest:
        a torn or corrupt checkpoint is skipped with a warning and the
        previous one restores instead (``CheckpointCorruptError`` only when
        *no* intact checkpoint remains). An explicit ``step`` never falls
        back. Template mismatches (``CheckpointMismatchError``) always raise
        — a wrong template is a caller bug, not disk corruption.

        ``shardings``: optional matching pytree of NamedSharding — the elastic
        path: leaves are placed directly with the *new* mesh layout.
        """
        candidates = [step] if step is not None else \
            list(reversed(self.all_steps()))
        if not candidates:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        last_err: Optional[Exception] = None
        for s in candidates:
            path = os.path.join(self.dir, f"step_{s:08d}")
            try:
                tree = load_checkpoint(path, template)
            except (CheckpointCorruptError, OSError) as e:
                if step is not None:
                    raise
                last_err = e
                warnings.warn(f"checkpoint step {s} is torn/corrupt ({e}); "
                              "falling back to the previous checkpoint")
                continue
            if shardings is not None:
                tree = jax.tree_util.tree_map(
                    lambda a, sh: jax.device_put(a, sh) if sh is not None else
                    jax.device_put(a), tree, shardings)
            return s, tree
        raise CheckpointCorruptError(
            f"no intact checkpoint under {self.dir}") from last_err


def load_checkpoint(path: str, template: Any) -> Any:
    """Load one checkpoint dir into ``template``'s structure, verifying each
    leaf's CRC32/shape against the manifest and shape+dtype against the
    template (typed ``CheckpointCorruptError`` / ``CheckpointMismatchError``
    naming the offending leaf)."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable manifest ({e})") from e
    leaves_meta = manifest["leaves"]
    paths = jax.tree_util.tree_leaves_with_path(template)
    vals = []
    for kpath, leaf in paths:
        key = jax.tree_util.keystr(kpath)
        if key not in leaves_meta:
            raise CheckpointMismatchError(f"checkpoint missing leaf {key}")
        meta = leaves_meta[key]
        try:
            arr = np.load(os.path.join(path, meta["file"]))
        except Exception as e:  # torn file, truncated header, bad magic, ...
            raise CheckpointCorruptError(
                f"{path}: leaf {key} unreadable ({e})") from e
        if tuple(arr.shape) != tuple(meta["shape"]) or \
                str(arr.dtype) != meta["dtype"]:
            raise CheckpointCorruptError(
                f"{path}: leaf {key} disagrees with its manifest entry "
                f"({arr.shape}/{arr.dtype} vs "
                f"{tuple(meta['shape'])}/{meta['dtype']})")
        crc = meta.get("crc32")  # absent in pre-CRC checkpoints
        if crc is not None and _crc(arr) != crc:
            raise CheckpointCorruptError(
                f"{path}: leaf {key} checksum mismatch")
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise CheckpointMismatchError(
                f"shape mismatch for {key}: {arr.shape} vs {expect}")
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and str(arr.dtype) != str(want_dtype):
            raise CheckpointMismatchError(
                f"dtype mismatch for {key}: {arr.dtype} vs {want_dtype}")
        vals.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)
