from repro.checkpoint.manager import (CheckpointCorruptError, CheckpointError,
                                      CheckpointManager,
                                      CheckpointMismatchError,
                                      CheckpointWriteError, load_checkpoint)

__all__ = ["CheckpointManager", "load_checkpoint", "CheckpointError",
           "CheckpointWriteError", "CheckpointCorruptError",
           "CheckpointMismatchError"]
